//! `arco` — the leader binary: tune, compare, and regenerate the paper's
//! tables and figures from the command line.
//!
//! ```text
//! arco tune          --model resnet18 --framework arco [--config configs/arco.json]
//! arco compare       --models alexnet,resnet18 --frameworks autotvm,chameleon,arco
//! arco fig4          --model resnet18            # CS ablation trace
//! arco serve-measure --addr 127.0.0.1:4917       # measurement fleet shard
//! arco serve-tune    --addr 127.0.0.1:4918       # tuning-as-a-service daemon
//! arco tune submit   --addr 127.0.0.1:4918 --model alexnet --wait   # remote client
//! arco journal merge out.jsonl a.jsonl b.jsonl   # union shard journals
//! arco journal compact fleet.jsonl               # GC a long-lived journal
//! arco store stat results/store                  # shared-store shape
//! arco store prune results/store --budget-kib N  # bound a shared store
//! arco report-models                             # Table 3
//! arco info                                      # backend / artifact status
//! ```
//!
//! Measurement-engine options (all commands): `--backend
//! vta-sim|analytical|remote:host:port[,...]` selects the measurement
//! oracle (or a fleet of `serve-measure` shards), `--workers N` sizes its
//! thread pool, `--journal results/journal.jsonl` persists measurements
//! for reuse across runs, `--no-cache` disables in-memory memoization,
//! `--cache-cap N` bounds the cache to N entries (LRU), `--placement
//! uniform|weighted` picks how a fleet splits batches across shards, and
//! `--pipeline-depth N` overlaps strategy compute with in-flight
//! measurement (1 = serial paper-faithful default).

use arco::config::RunConfig;
use arco::eval::{self, BackendKind, BackendSpec, Placement};
use arco::report;
use arco::tuner::{compare_frameworks_opts, tune_model_with, DriverOptions, Fidelity, Framework};
use arco::util::cli::Cli;
use arco::util::json::write_json_file;
use arco::util::log::{set_level, Level};
use arco::workload::{model_by_name, model_names};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

fn main() {
    arco::util::log::init_from_env();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn usage() -> String {
    "arco <command> [options]\n\ncommands:\n  \
     tune           tune one model with one framework, in-process\n  \
     tune submit    submit jobs to a serve-tune daemon (also: tune status|results|cancel)\n  \
     compare        compare frameworks across models (Figs 5-7, Table 6)\n  \
     fig4           ARCO with/without Confidence Sampling trace (Fig 4)\n  \
     serve-measure  expose a measurement backend to remote tuners (fleet shard)\n  \
     serve-tune     tuning-as-a-service daemon: queue remote jobs over one shared engine\n  \
     journal        measurement-journal tooling (merge, compact, synth)\n  \
     store          shared measurement-store tooling (stat, prune)\n  \
     devcheck       static-analysis pass enforcing the eval-layer invariants\n  \
     report-models  print the model zoo (Table 3)\n  \
     info           backend / artifact status\n\nrun `arco <command> --help` for options\n"
        .into()
}

fn run(args: &[String]) -> anyhow::Result<()> {
    let Some(cmd) = args.first() else {
        print!("{}", usage());
        return Ok(());
    };
    let rest = &args[1..];
    match cmd.as_str() {
        // `arco tune` doubles as the serve-tune client: a daemon-facing
        // subcommand word routes to the wire client, anything else to the
        // in-process tuner.
        "tune" => match rest.first().map(String::as_str) {
            Some("submit" | "status" | "results" | "cancel") => cmd_tune_client(rest),
            _ => cmd_tune(rest),
        },
        "compare" => cmd_compare(rest),
        "fig4" => cmd_fig4(rest),
        "serve-measure" => cmd_serve_measure(rest),
        "serve-tune" => cmd_serve_tune(rest),
        "journal" => cmd_journal(rest),
        "store" => cmd_store(rest),
        "devcheck" => cmd_devcheck(rest),
        "report-models" => {
            print!("{}", report::table3_models());
            report::write_result("table3_models.md", &report::table3_models())?;
            Ok(())
        }
        "info" => cmd_info(),
        "--help" | "-h" | "help" => {
            print!("{}", usage());
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'\n\n{}", usage()),
    }
}

/// `arco devcheck [root]` — run the in-tree static-analysis pass over
/// the repository at `root` (default: the current directory). Exits
/// non-zero when any invariant is violated, so CI can gate on it.
fn cmd_devcheck(rest: &[String]) -> anyhow::Result<()> {
    if matches!(rest.first().map(String::as_str), Some("--help" | "-h")) {
        println!(
            "arco devcheck [root]\n\nstatic-analysis pass over rust/src and docs/ \
             enforcing the eval-layer\ninvariants ({}).\nSuppress one finding with \
             `// devcheck:allow(<rule>)` on or above its line.",
            arco::devcheck::RULES.join(", ")
        );
        return Ok(());
    }
    let root = rest
        .first()
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("."));
    let code = arco::devcheck::run(&root)?;
    if code != 0 {
        anyhow::bail!("devcheck found invariant violations (listed above)");
    }
    Ok(())
}

fn common_cli(name: &str, about: &str) -> Cli {
    Cli::new(name, about)
        .opt("config", Some('c'), "JSON config file (configs/*.json)", None)
        .opt("trials", Some('n'), "total hardware measurements per task", None)
        .opt("batch", Some('b'), "measurements per planning iteration", None)
        .opt("seed", Some('s'), "RNG seed", None)
        .opt("workers", Some('w'), "measurement engine worker threads", None)
        .opt(
            "backend",
            None,
            "measurement backend: vta-sim | analytical | remote:host:port[,host:port...]",
            None,
        )
        .opt("journal", Some('j'), "persistent measurement journal (JSONL path)", None)
        .opt("cache-cap", None, "bound the measurement cache to N entries (LRU)", None)
        .opt(
            "placement",
            None,
            "fleet batch placement: uniform (reproducible default) | weighted \
             (throughput-proportional chunks for heterogeneous fleets)",
            None,
        )
        .opt(
            "pipeline-depth",
            None,
            "measurement batches in flight per tuning job: 1 (serial, paper-faithful \
             default) | N>=2 (pipelined speed mode: plan batch k+1 while batch k measures)",
            None,
        )
        .opt(
            "fidelity",
            None,
            "evaluation tier: exact (every planned point simulated, bit-identical \
             default) | screen:<keep>[:<explore>] (calibrated analytical screening keeps \
             the top <keep> fraction of each batch for the simulator, plus an <explore> \
             exploration slice of the rest)",
            None,
        )
        .flag("no-cache", None, "disable the measurement cache (every point re-simulated)")
        .flag("quick", Some('q'), "CI-scale RL budgets (same pipeline)")
        .flag("verbose", Some('v'), "debug logging")
        .flag("help", Some('h'), "show help")
}

fn load_config(a: &arco::util::cli::Args) -> anyhow::Result<(RunConfig, bool)> {
    let mut cfg = match a.get("config") {
        Some(path) => RunConfig::from_file(Path::new(path))?,
        None => RunConfig::default(),
    };
    if let Some(n) = a.get_usize("trials").map_err(anyhow::Error::msg)? {
        cfg.budget.total_measurements = n;
    }
    if let Some(b) = a.get_usize("batch").map_err(anyhow::Error::msg)? {
        cfg.budget.batch = b;
    }
    if let Some(w) = a.get_usize("workers").map_err(anyhow::Error::msg)? {
        cfg.budget.workers = w;
    }
    if let Some(d) = a.get_usize("pipeline-depth").map_err(anyhow::Error::msg)? {
        cfg.budget.pipeline_depth = d.max(1);
    }
    if let Some(name) = a.get("fidelity") {
        cfg.budget.fidelity = Fidelity::parse(name).ok_or_else(|| {
            anyhow::anyhow!(
                "bad --fidelity '{name}' (expected exact | screen:<keep>[:<explore>] with \
                 0 < keep <= 1 and 0 <= explore <= 1)"
            )
        })?;
    }
    if let Some(s) = a.get_u64("seed").map_err(anyhow::Error::msg)? {
        cfg.seed = s;
    }
    if let Some(name) = a.get("backend") {
        cfg.eval.backend = BackendSpec::parse(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown backend '{name}' (known: {}, or remote:host:port[,host:port...])",
                BackendKind::known_names().join(", ")
            )
        })?;
    }
    if a.has_flag("no-cache") {
        cfg.eval.cache = false;
    }
    if let Some(cap) = a.get_usize("cache-cap").map_err(anyhow::Error::msg)? {
        cfg.eval.cache_capacity = Some(cap);
    }
    if let Some(path) = a.get("journal") {
        cfg.eval.journal = Some(PathBuf::from(path));
    }
    if let Some(name) = a.get("placement") {
        cfg.eval.placement = Placement::from_name(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown placement '{name}' (known: {})",
                Placement::known_names().join(", ")
            )
        })?;
    }
    if a.has_flag("verbose") {
        set_level(Level::Debug);
    }
    Ok((cfg, a.has_flag("quick")))
}

/// One measurement engine per run: shared cache and journal across every
/// framework, model and task the command touches. Fails fast on an unsafe
/// journal (locked by another writer, foreign fingerprint) or an
/// unreachable remote fleet.
fn build_engine(cfg: &RunConfig) -> anyhow::Result<eval::Engine> {
    eval::Engine::new(cfg.eval.engine_config(cfg.budget.workers))
}

/// When a screening fidelity is active, attach calibration state so every
/// fresh simulator point refines the analytical overlap model. With a
/// journal configured the state persists in a fingerprint-gated sidecar
/// next to it (returned here so the run can save it back on exit); without
/// one the calibration starts from the seed constants and lives for the
/// run only.
fn setup_calibration(engine: &eval::Engine, cfg: &RunConfig) -> Option<PathBuf> {
    if !cfg.budget.fidelity.is_screen() {
        return None;
    }
    let fp = eval::Fingerprint::current();
    match &cfg.eval.journal {
        Some(journal) => {
            let sidecar = eval::Calibration::sidecar_path(journal);
            let calib = eval::Calibration::load_or_new(&sidecar, &fp);
            arco::log_info!(
                "main",
                "screening fidelity {}: calibration sidecar {} ({} observations)",
                cfg.budget.fidelity.describe(),
                sidecar.display(),
                calib.observations()
            );
            engine.attach_calibration(Arc::new(calib));
            Some(sidecar)
        }
        None => {
            engine.attach_calibration(Arc::new(eval::Calibration::new(fp)));
            None
        }
    }
}

/// Persist the run's calibration state back to the journal sidecar (no-op
/// when screening is off or no journal is configured).
fn save_calibration(engine: &eval::Engine, sidecar: Option<PathBuf>) {
    let (Some(path), Some(calib)) = (sidecar, engine.calibration()) else {
        return;
    };
    match calib.save(&path) {
        Ok(()) => arco::log_info!(
            "main",
            "saved calibration sidecar {} ({} observations)",
            path.display(),
            calib.observations()
        ),
        Err(e) => {
            arco::log_warn!("main", "failed to save calibration sidecar {}: {e}", path.display())
        }
    }
}

fn parse_models(spec: &str) -> anyhow::Result<Vec<String>> {
    let names: Vec<String> = if spec == "all" {
        model_names().iter().map(|s| s.to_string()).collect()
    } else {
        spec.split(',').map(|s| s.trim().to_string()).collect()
    };
    for n in &names {
        if model_by_name(n).is_none() {
            anyhow::bail!("unknown model '{n}' (known: {})", model_names().join(", "));
        }
    }
    Ok(names)
}

fn cmd_tune(args: &[String]) -> anyhow::Result<()> {
    let cli = common_cli("arco tune", "tune one model with one framework")
        .opt("model", Some('m'), "zoo model name", Some("resnet18"))
        .opt("framework", Some('f'), "autotvm|chameleon|arco|random|arco-nocs|arco-swonly", Some("arco"));
    let a = cli.parse(args).map_err(anyhow::Error::msg)?;
    if a.has_flag("help") {
        print!("{}", cli.usage());
        return Ok(());
    }
    let (cfg, quick) = load_config(&a)?;
    let model_name = a.get("model").unwrap();
    let model = model_by_name(model_name).ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let framework = Framework::from_name(a.get("framework").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown framework"))?;

    let engine = build_engine(&cfg)?;
    let calib_sidecar = setup_calibration(&engine, &cfg);
    let out = tune_model_with(&engine, framework, &model, cfg.budget, quick, cfg.seed)?;
    save_calibration(&engine, calib_sidecar);
    println!(
        "{} on {}: mean inference {:.5}s ({:.3} inf/s), compile {:.1}s, {} measurements",
        framework.name(),
        model.name,
        out.inference_secs,
        out.throughput(),
        out.compile_secs,
        out.measurements
    );
    for t in &out.tasks {
        println!(
            "  {}  x{}  best {:.3e}s  ({:.1} GFLOPS, {} invalid)",
            t.task_id, t.weight, t.result.best.seconds, t.result.best.gflops, t.result.invalid
        );
    }
    // Phase profile (merged across tasks): where the search wall-clock went.
    let mut merged = arco::util::timer::PhaseTimer::new();
    for t in &out.tasks {
        merged.merge(&t.result.timer);
    }
    println!("\nsearch phase profile:\n{}", merged.summary());
    println!("eval engine: {}", engine.summary());
    let json = report::compare_json(&[arco::tuner::CompareReport {
        model: model.name.to_string(),
        outcomes: vec![out],
        ledger: None,
    }]);
    let path = Path::new("results").join(format!("tune_{}_{}.json", framework.name(), model.name));
    write_json_file(&path, &json)?;
    println!("wrote {}", path.display());
    Ok(())
}

fn cmd_compare(args: &[String]) -> anyhow::Result<()> {
    let cli = common_cli("arco compare", "compare frameworks (Figs 5-7, Table 6)")
        .opt("models", Some('m'), "comma-separated zoo models, or 'all'", Some("all"))
        .opt("frameworks", Some('f'), "comma-separated frameworks", Some("autotvm,chameleon,arco"))
        .flag(
            "shared-budget",
            None,
            "equal-budget protocol: run every (framework, task) job concurrently over a \
             shared per-task measurement ledger (measure once, charge everyone)",
        );
    let a = cli.parse(args).map_err(anyhow::Error::msg)?;
    if a.has_flag("help") {
        print!("{}", cli.usage());
        return Ok(());
    }
    let (cfg, quick) = load_config(&a)?;
    let models = parse_models(a.get("models").unwrap())?;
    let frameworks: Vec<Framework> = a
        .get("frameworks")
        .unwrap()
        .split(',')
        .map(|s| {
            Framework::from_name(s.trim())
                .ok_or_else(|| anyhow::anyhow!("unknown framework '{s}'"))
        })
        .collect::<Result<_, _>>()?;
    let mut driver = cfg.driver;
    if a.has_flag("shared-budget") {
        driver = DriverOptions { concurrent: true, shared_budget: true };
    }

    let engine = build_engine(&cfg)?;
    let calib_sidecar = setup_calibration(&engine, &cfg);
    let mut reports = Vec::new();
    for name in &models {
        let model = model_by_name(name).unwrap();
        arco::log_info!("main", "=== comparing on {name} ===");
        reports.push(compare_frameworks_opts(
            &engine, &frameworks, &model, cfg.budget, quick, cfg.seed, driver,
        )?);
    }
    save_calibration(&engine, calib_sidecar);
    println!("eval engine: {}", engine.summary());
    for (addr, stats) in engine.fleet_stats() {
        println!("  shard {addr}: {}", stats.dump());
    }
    // Fleet placement: where the points went, per shard (written to the
    // report dir so heterogeneous-fleet runs leave an audit trail).
    let engine_stats = engine.stats();
    if !engine_stats.placement.is_empty() {
        let md = report::placement_md(cfg.eval.placement.name(), &engine_stats);
        print!("{md}");
        report::write_result("fleet_placement.md", &md)?;
    }
    for r in &reports {
        if let Some(ledger) = &r.ledger {
            println!("ledger[{}]: {}", r.model, ledger.summary());
            report::write_result(
                &format!("ledger_{}.md", r.model),
                &report::ledger_stats_md(ledger),
            )?;
        }
    }

    let t6 = report::table6_inference(&reports);
    println!("\nTable 6 — mean inference times (s) on VTA++:\n{t6}");
    println!("{}", report::fig5_summary(&reports));
    report::write_result("table6_inference.md", &t6)?;
    report::write_result("fig5_throughput.csv", &report::fig5_throughput(&reports))?;
    report::write_result("fig5_summary.txt", &report::fig5_summary(&reports))?;
    report::write_result("fig6_compile_time.csv", &report::fig6_compile_time(&reports))?;
    for r in &reports {
        report::write_result(
            &format!("fig7_convergence_{}.csv", r.model),
            &report::fig7_convergence(r),
        )?;
    }
    write_json_file(Path::new("results/compare.json"), &report::compare_json(&reports))?;
    println!("wrote results/table6_inference.md, fig5_*.csv, fig6_compile_time.csv, fig7_convergence_*.csv, compare.json");
    Ok(())
}

fn cmd_fig4(args: &[String]) -> anyhow::Result<()> {
    let cli = common_cli("arco fig4", "ARCO with vs without Confidence Sampling")
        .opt("model", Some('m'), "zoo model name", Some("resnet18"));
    let a = cli.parse(args).map_err(anyhow::Error::msg)?;
    if a.has_flag("help") {
        print!("{}", cli.usage());
        return Ok(());
    }
    let (cfg, quick) = load_config(&a)?;
    let model = model_by_name(a.get("model").unwrap())
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;

    // Both variants share one engine: configurations the two runs have in
    // common are simulated once.
    let engine = build_engine(&cfg)?;
    let calib_sidecar = setup_calibration(&engine, &cfg);
    let with_cs = tune_model_with(&engine, Framework::Arco, &model, cfg.budget, quick, cfg.seed)?;
    let without_cs =
        tune_model_with(&engine, Framework::ArcoNoCs, &model, cfg.budget, quick, cfg.seed)?;
    save_calibration(&engine, calib_sidecar);

    // Heaviest task's trace under each variant.
    let pick = |o: &arco::tuner::ModelOutcome| {
        o.tasks
            .iter()
            .max_by_key(|t| t.result.trace.len())
            .map(|t| t.result.trace.clone())
            .unwrap_or_default()
    };
    let csv = report::fig4_configs_over_time(
        "after_cs",
        &pick(&with_cs),
        "before_cs",
        &pick(&without_cs),
    );
    report::write_result(&format!("fig4_cs_{}.csv", model.name), &csv)?;
    println!(
        "fig4: with CS best {:.5}s ({} measurements), without CS best {:.5}s ({} measurements)",
        with_cs.inference_secs, with_cs.measurements, without_cs.inference_secs, without_cs.measurements
    );
    println!("eval engine: {}", engine.summary());
    println!("wrote results/fig4_cs_{}.csv", model.name);
    Ok(())
}

fn cmd_serve_measure(args: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new("arco serve-measure", "expose a measurement backend to remote tuners")
        .opt("addr", Some('a'), "listen address (port 0 picks a free port)", Some("127.0.0.1:4917"))
        .opt("backend", None, "local backend to serve: vta-sim | analytical", Some("vta-sim"))
        .opt("workers", Some('w'), "measurement worker threads", None)
        .opt("journal", Some('j'), "persistent measurement journal (JSONL path)", None)
        .opt(
            "warm-start",
            None,
            "read-only journal (e.g. `arco journal merge` output) preloaded into the cache \
             before accepting batches",
            None,
        )
        .opt("cache-cap", None, "bound the measurement cache to N entries (LRU)", None)
        .opt(
            "throttle-ms",
            None,
            "artificial per-point service latency in ms (scenario tests and placement \
             benchmarks; 0 = off)",
            None,
        )
        .opt(
            "store",
            None,
            "shared measurement store directory: answer points any tenant ever measured, \
             append fresh ones for everyone (fleet-wide \"measure once, ever\")",
            None,
        )
        .opt("store-segment-kib", None, "store segment rotation threshold in KiB", None)
        .opt("store-budget-kib", None, "store directory byte budget in KiB", None)
        .flag("no-cache", None, "disable the measurement cache")
        .flag("verbose", Some('v'), "debug logging")
        .flag("help", Some('h'), "show help");
    let a = cli.parse(args).map_err(anyhow::Error::msg)?;
    if a.has_flag("help") {
        print!("{}", cli.usage());
        return Ok(());
    }
    if a.has_flag("verbose") {
        set_level(Level::Debug);
    }
    let name = a.get("backend").unwrap();
    let backend = match BackendSpec::parse(name) {
        Some(BackendSpec::Builtin(kind)) => kind,
        Some(BackendSpec::Remote(_)) => {
            anyhow::bail!("serve-measure serves a local backend; chaining remote shards is not supported")
        }
        None => anyhow::bail!(
            "unknown backend '{name}' (known: {})",
            BackendKind::known_names().join(", ")
        ),
    };
    let store = match a.get("store") {
        Some(dir) => {
            let mut cfg = eval::StoreConfig::new(PathBuf::from(dir));
            if let Some(kib) = a.get_u64("store-segment-kib").map_err(anyhow::Error::msg)? {
                cfg.segment_bytes = kib.saturating_mul(1024).max(1);
            }
            if let Some(kib) = a.get_u64("store-budget-kib").map_err(anyhow::Error::msg)? {
                cfg.budget_bytes = kib.saturating_mul(1024).max(1);
            }
            Some(cfg)
        }
        None => None,
    };
    let config = eval::EngineConfig {
        backend: backend.into(),
        workers: a
            .get_usize("workers")
            .map_err(anyhow::Error::msg)?
            .unwrap_or_else(arco::util::pool::default_workers),
        cache: !a.has_flag("no-cache"),
        cache_capacity: a.get_usize("cache-cap").map_err(anyhow::Error::msg)?,
        journal: a.get("journal").map(PathBuf::from),
        warm_start: a.get("warm-start").map(PathBuf::from),
        store,
        placement: Placement::default(),
    };
    let store_dir = config.store.as_ref().map(|c| c.dir.clone());
    let engine = Arc::new(eval::Engine::new(config)?);
    let throttle_ms = a.get_usize("throttle-ms").map_err(anyhow::Error::msg)?.unwrap_or(0);
    let opts = eval::ServeOptions {
        measure_delay: Duration::from_millis(throttle_ms as u64),
        ..eval::ServeOptions::default()
    };
    let handle = eval::serve_measure_with(a.get("addr").unwrap(), Arc::clone(&engine), opts)?;
    // The address line is machine-read by fleet launch scripts (CI smoke):
    // keep its format stable.
    println!("serve-measure: listening on {}", handle.addr());
    println!(
        "serve-measure: backend={} workers={} preloaded={} fingerprint [{}]",
        engine.backend_name(),
        engine.workers(),
        engine.preloaded_entries(),
        eval::Fingerprint::current().describe()
    );
    if let Some(dir) = store_dir {
        println!("serve-measure: shared store at {}", dir.display());
    }
    if throttle_ms > 0 {
        println!("serve-measure: throttled {throttle_ms} ms/point (testing mode)");
    }
    handle.wait();
    Ok(())
}

fn cmd_serve_tune(args: &[String]) -> anyhow::Result<()> {
    let cli = Cli::new(
        "arco serve-tune",
        "tuning-as-a-service daemon: accept jobs from remote clients over one shared engine",
    )
    .opt("addr", Some('a'), "listen address (port 0 picks a free port)", Some("127.0.0.1:4918"))
    .opt(
        "backend",
        None,
        "measurement backend the daemon tunes over: vta-sim | analytical | \
         remote:host:port[,host:port...] (a serve-measure fleet)",
        Some("vta-sim"),
    )
    .opt("workers", Some('w'), "measurement engine worker threads", None)
    .opt("journal", Some('j'), "persistent measurement journal (JSONL path)", None)
    .opt(
        "warm-start",
        None,
        "read-only journal (e.g. `arco journal merge` output) preloaded into the cache \
         before the first job",
        None,
    )
    .opt("cache-cap", None, "bound the measurement cache to N entries (LRU)", None)
    .opt(
        "placement",
        None,
        "fleet batch placement: uniform (reproducible default) | weighted \
         (throughput-proportional chunks for heterogeneous fleets)",
        None,
    )
    .opt(
        "quota",
        None,
        "measurement points each (client, task) account may spend over the daemon's \
         lifetime (admission control; default: unmetered)",
        None,
    )
    .opt(
        "jobs",
        None,
        "concurrent job-runner threads (queued jobs beyond this wait FIFO)",
        Some("2"),
    )
    .opt(
        "trace-cap",
        None,
        "trace entries retained per job for pagination (0 = unbounded; clients that fall \
         behind a bounded window get a stale-cursor error)",
        Some("0"),
    )
    .flag("no-cache", None, "disable the measurement cache")
    .flag("verbose", Some('v'), "debug logging")
    .flag("help", Some('h'), "show help");
    let a = cli.parse(args).map_err(anyhow::Error::msg)?;
    if a.has_flag("help") {
        print!("{}", cli.usage());
        return Ok(());
    }
    if a.has_flag("verbose") {
        set_level(Level::Debug);
    }
    let name = a.get("backend").unwrap();
    let backend = BackendSpec::parse(name).ok_or_else(|| {
        anyhow::anyhow!(
            "unknown backend '{name}' (known: {}, or remote:host:port[,host:port...])",
            BackendKind::known_names().join(", ")
        )
    })?;
    let placement = match a.get("placement") {
        Some(p) => Placement::from_name(p).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown placement '{p}' (known: {})",
                Placement::known_names().join(", ")
            )
        })?,
        None => Placement::default(),
    };
    let config = eval::EngineConfig {
        backend,
        workers: a
            .get_usize("workers")
            .map_err(anyhow::Error::msg)?
            .unwrap_or_else(arco::util::pool::default_workers),
        cache: !a.has_flag("no-cache"),
        cache_capacity: a.get_usize("cache-cap").map_err(anyhow::Error::msg)?,
        journal: a.get("journal").map(PathBuf::from),
        warm_start: a.get("warm-start").map(PathBuf::from),
        store: None,
        placement,
    };
    let engine = Arc::new(eval::Engine::new(config)?);
    // With a journal configured, calibration persists next to it so
    // screening jobs (`--fidelity screen:...` at submit) start from state
    // refined by every prior fresh measurement the daemon made; attaching
    // is free for exact jobs (results are untouched).
    let calib_sidecar = a.get("journal").map(|j| {
        let sidecar = eval::Calibration::sidecar_path(Path::new(j));
        let fp = eval::Fingerprint::current();
        engine.attach_calibration(Arc::new(eval::Calibration::load_or_new(&sidecar, &fp)));
        sidecar
    });
    let opts = eval::TuneServeOptions {
        quota: a.get_usize("quota").map_err(anyhow::Error::msg)?.unwrap_or(usize::MAX),
        runners: a.get_usize("jobs").map_err(anyhow::Error::msg)?.unwrap_or(2).max(1),
        trace_cap: a.get_usize("trace-cap").map_err(anyhow::Error::msg)?.unwrap_or(0),
    };
    let handle = eval::spawn_tune(a.get("addr").unwrap(), Arc::clone(&engine), opts)?;
    // The address line is machine-read by launch scripts (CI smoke): keep
    // its format stable, exactly like serve-measure's.
    println!("serve-tune: listening on {}", handle.addr());
    let quota = if opts.quota == usize::MAX {
        "unmetered".to_string()
    } else {
        opts.quota.to_string()
    };
    println!(
        "serve-tune: backend={} workers={} runners={} quota={quota} trace-cap={} fingerprint [{}]",
        engine.backend_name(),
        engine.workers(),
        opts.runners,
        opts.trace_cap,
        eval::Fingerprint::current().describe()
    );
    handle.wait();
    save_calibration(&engine, calib_sidecar);
    Ok(())
}

/// Shared options of every `arco tune <sub>` daemon-client subcommand.
fn tune_client_cli(name: &str, about: &str) -> Cli {
    Cli::new(name, about)
        .opt("addr", Some('a'), "serve-tune daemon address", Some("127.0.0.1:4918"))
        .opt("client", None, "identity to connect as (the daemon's quota account key)", Some("cli"))
        .flag("verbose", Some('v'), "debug logging")
        .flag("help", Some('h'), "show help")
}

fn tune_connect(a: &arco::util::cli::Args) -> anyhow::Result<eval::TuneClient> {
    if a.has_flag("verbose") {
        set_level(Level::Debug);
    }
    eval::TuneClient::connect(a.get("addr").unwrap(), a.get("client").unwrap())
}

fn print_job_status(s: &eval::JobStatus) {
    let first = match s.first_result_secs {
        Some(t) => format!("{t:.2}s"),
        None => "-".to_string(),
    };
    print!(
        "job {:<4} {:<9} {}/{}  {}  measured={} charged={} best={:.1} GFLOPS  first-result={first}",
        s.id, s.state.name(), s.client, s.framework, s.task_id, s.measured, s.charged, s.best_gflops
    );
    match &s.error {
        Some(e) => println!("  error: {e}"),
        None => println!(),
    }
}

fn print_trace_entries(entries: &[arco::tuner::TraceEntry]) {
    for e in entries {
        println!(
            "{},{},{:.6},{:.3},{:.3},{}",
            e.ordinal, e.iteration, e.at_secs, e.gflops, e.best_gflops, e.valid
        );
    }
}

fn print_outcome(o: &eval::JobOutcome) {
    println!(
        "# outcome: best {:.3e}s ({:.1} GFLOPS)  measured={} fresh={} cache_served={} \
         invalid={} modeled_hw={:.1}s wall={:.1}s",
        o.best.seconds,
        o.best.gflops,
        o.measurements,
        o.fresh,
        o.cache_served,
        o.invalid,
        o.modeled_hw_secs,
        o.wall_secs
    );
}

/// `arco tune submit|status|results|cancel` — the wire client for a
/// `serve-tune` daemon. Plain `arco tune` (no subcommand word) stays the
/// in-process tuner; `run` routes before parsing.
fn cmd_tune_client(args: &[String]) -> anyhow::Result<()> {
    match args.first().map(String::as_str) {
        Some("submit") => {
            let cli = tune_client_cli(
                "arco tune submit",
                "submit one tuning job per unique task of a model to a serve-tune daemon",
            )
            .opt("model", Some('m'), "zoo model name", Some("resnet18"))
            .opt(
                "framework",
                Some('f'),
                "autotvm|chameleon|arco|random|arco-nocs|arco-swonly",
                Some("arco"),
            )
            .opt("trials", Some('n'), "total hardware measurements per task", Some("1000"))
            .opt("batch", Some('b'), "measurements per planning iteration", Some("64"))
            .opt(
                "pipeline-depth",
                None,
                "measurement batches in flight per job (1 = serial, bit-identical to the \
                 in-process driver on the same seed)",
                Some("1"),
            )
            .opt(
                "seed",
                Some('s'),
                "RNG seed (task i runs at seed ^ i << 32, like `arco tune`)",
                Some("1"),
            )
            .opt(
                "fidelity",
                None,
                "exact | screen:<keep>[:<explore>] — analytical screening tier",
                Some("exact"),
            )
            .opt("page", None, "trace entries per page while --wait streams", Some("256"))
            .opt("poll-ms", None, "delay between empty pages while --wait streams", Some("50"))
            .flag("quick", Some('q'), "CI-scale RL budgets (same pipeline)")
            .flag("wait", None, "stream every job to completion and print outcomes")
            .flag("help", Some('h'), "show help");
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                return Ok(());
            }
            let model_name = a.get("model").unwrap();
            let model = model_by_name(model_name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown model '{model_name}' (known: {})",
                    model_names().join(", ")
                )
            })?;
            let framework = Framework::from_name(a.get("framework").unwrap())
                .ok_or_else(|| anyhow::anyhow!("unknown framework"))?;
            let trials = a.get_usize("trials").map_err(anyhow::Error::msg)?.unwrap();
            let batch = a.get_usize("batch").map_err(anyhow::Error::msg)?.unwrap();
            let depth =
                a.get_usize("pipeline-depth").map_err(anyhow::Error::msg)?.unwrap().max(1);
            let seed = a.get_u64("seed").map_err(anyhow::Error::msg)?.unwrap();
            let fidelity_str = a.get("fidelity").unwrap();
            let fidelity = Fidelity::parse(fidelity_str).ok_or_else(|| {
                anyhow::anyhow!(
                    "bad --fidelity '{fidelity_str}' (expected exact | screen:<keep>[:<explore>] \
                     with 0 < keep <= 1 and 0 <= explore <= 1)"
                )
            })?;
            let quick = a.has_flag("quick");
            let mut client = tune_connect(&a)?;
            println!(
                "tune submit: daemon {} backend={} (as client '{}')",
                a.get("addr").unwrap(),
                client.backend(),
                client.client()
            );
            let uniq = model.unique_tasks();
            let mut jobs = Vec::new();
            for (i, (task, weight)) in uniq.iter().enumerate() {
                let spec = eval::JobSpec {
                    client: client.client().to_string(),
                    framework,
                    task: *task,
                    trials,
                    batch,
                    pipeline_depth: depth,
                    // Same per-task derivation as the in-process driver, so
                    // a depth-1 job reproduces `arco tune` bit-for-bit.
                    seed: seed ^ (i as u64) << 32,
                    quick,
                    fidelity,
                };
                let (id, position) = client.submit(spec)?;
                println!(
                    "submitted job {id} (queue position {position}): {} {} x{weight}",
                    framework.name(),
                    task.short_id()
                );
                jobs.push((id, task.short_id(), *weight));
            }
            if a.has_flag("wait") {
                let page = a.get_usize("page").map_err(anyhow::Error::msg)?.unwrap().max(1);
                let poll_ms = a.get_usize("poll-ms").map_err(anyhow::Error::msg)?.unwrap();
                let poll = Duration::from_millis(poll_ms as u64);
                let (mut measured, mut fresh, mut cache_served) = (0usize, 0usize, 0usize);
                let mut weighted_secs = 0.0f64;
                let mut failed = Vec::new();
                for (id, task_id, weight) in &jobs {
                    let done = client.wait(*id, page, poll)?;
                    if let Some(o) = &done.outcome {
                        let screened_note = if o.screened > 0 {
                            format!(" screened={}", o.screened)
                        } else {
                            String::new()
                        };
                        println!(
                            "  job {id} {task_id}  x{weight}  best {:.3e}s  ({:.1} GFLOPS)  \
                             measured={} fresh={} cache_served={} invalid={}{} [{}]",
                            o.best.seconds,
                            o.best.gflops,
                            o.measurements,
                            o.fresh,
                            o.cache_served,
                            o.invalid,
                            screened_note,
                            done.status.state.name()
                        );
                        measured += o.measurements;
                        fresh += o.fresh;
                        cache_served += o.cache_served;
                        weighted_secs += *weight as f64 * o.best.seconds;
                    } else {
                        let msg = done
                            .status
                            .error
                            .clone()
                            .unwrap_or_else(|| "no outcome".to_string());
                        println!("  job {id} {task_id}: {} ({msg})", done.status.state.name());
                        failed.push((*id, msg));
                    }
                }
                // The summary line is grepped by the CI smoke pass (shared
                // daemon cache: second client's jobs land fresh=0).
                println!(
                    "tune submit: {} on {}: weighted inference {:.5}s; measured={} fresh={} \
                     cache_served={}",
                    framework.name(),
                    model.name,
                    weighted_secs,
                    measured,
                    fresh,
                    cache_served
                );
                if let Some((id, msg)) = failed.first() {
                    anyhow::bail!(
                        "{} job(s) did not finish (first: job {id}: {msg})",
                        failed.len()
                    );
                }
            }
            Ok(())
        }
        Some("status") => {
            let cli = tune_client_cli(
                "arco tune status",
                "one job's status, or a paged listing of every job the daemon holds",
            )
            .opt("job", None, "job id (omit to list every job)", None)
            .opt("limit", None, "jobs per listing page", Some("64"));
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                return Ok(());
            }
            let mut client = tune_connect(&a)?;
            match a.get_u64("job").map_err(anyhow::Error::msg)? {
                Some(id) => print_job_status(&client.status(id)?),
                None => {
                    let limit = a.get_usize("limit").map_err(anyhow::Error::msg)?.unwrap().max(1);
                    let jobs = client.list_jobs(limit)?;
                    if jobs.is_empty() {
                        println!("no jobs");
                    }
                    for s in &jobs {
                        print_job_status(s);
                    }
                }
            }
            Ok(())
        }
        Some("results") => {
            let cli = tune_client_cli(
                "arco tune results",
                "stream one job's trace as CSV (one page, or --follow to completion)",
            )
            .opt("job", None, "job id", None)
            .opt("cursor", None, "resume after an earlier page's `# cursor:` token", None)
            .opt("limit", None, "trace entries per page", Some("256"))
            .opt("poll-ms", None, "delay between empty pages while --follow streams", Some("50"))
            .flag("follow", None, "page until the job is terminal and fully drained");
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                return Ok(());
            }
            let job = a
                .get_u64("job")
                .map_err(anyhow::Error::msg)?
                .ok_or_else(|| anyhow::anyhow!("--job is required: arco tune results --job N"))?;
            let limit = a.get_usize("limit").map_err(anyhow::Error::msg)?.unwrap().max(1);
            let mut client = tune_connect(&a)?;
            println!("ordinal,iteration,at_secs,gflops,best_gflops,valid");
            if a.has_flag("follow") {
                let poll_ms = a.get_usize("poll-ms").map_err(anyhow::Error::msg)?.unwrap();
                let done = client.wait(job, limit, Duration::from_millis(poll_ms as u64))?;
                print_trace_entries(&done.trace);
                if let Some(o) = &done.outcome {
                    print_outcome(o);
                }
                println!("# state: {}", done.status.state.name());
                if let Some(e) = &done.status.error {
                    println!("# error: {e}");
                }
            } else {
                let cursor = a.get("cursor").map(String::from);
                let page = client.trace_page(job, cursor, limit)?;
                print_trace_entries(&page.entries);
                println!("# cursor: {}", page.cursor);
                if let Some(o) = &page.outcome {
                    print_outcome(o);
                }
                if page.done {
                    println!("# done");
                }
            }
            Ok(())
        }
        Some("cancel") => {
            let cli = tune_client_cli(
                "arco tune cancel",
                "request cooperative cancellation of a job (takes effect at a batch boundary)",
            )
            .opt("job", None, "job id", None);
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                return Ok(());
            }
            let job = a
                .get_u64("job")
                .map_err(anyhow::Error::msg)?
                .ok_or_else(|| anyhow::anyhow!("--job is required: arco tune cancel --job N"))?;
            let mut client = tune_connect(&a)?;
            let state = client.cancel(job)?;
            println!("job {job}: {}", state.name());
            Ok(())
        }
        // `run` only routes the four words above here.
        _ => anyhow::bail!("unknown tune subcommand\n\n{}", usage()),
    }
}

fn cmd_journal(args: &[String]) -> anyhow::Result<()> {
    let sub_usage = "arco journal <subcommand>\n\nsubcommands:\n  \
         merge <out.jsonl> <in.jsonl...>  union fingerprint-identical journals \
         (dedup on backend+task+knobs)\n  \
         compact <file.jsonl>             rewrite a journal in place, dropping duplicate \
         records and records from foreign/stale fingerprints\n  \
         synth <out.jsonl> --records N    generate a synthetic warm-start journal of \
         measured random points (scale tests, codec benchmarks)\n";
    match args.first().map(String::as_str) {
        Some("synth") => {
            let cli = Cli::new(
                "arco journal synth",
                "generate a synthetic warm-start journal of measured random points",
            )
            .opt("records", Some('n'), "distinct records to generate", Some("1000"))
            .opt("model", Some('m'), "model whose tasks seed the workload shapes", Some("alexnet"))
            .opt(
                "backend",
                None,
                "backend measuring the points: vta-sim | analytical",
                Some("analytical"),
            )
            .opt("seed", Some('s'), "RNG seed", Some("1"))
            .flag("verbose", Some('v'), "debug logging")
            .flag("help", Some('h'), "show help");
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                println!("\nusage: arco journal synth <out.jsonl> [--records N]");
                return Ok(());
            }
            if a.has_flag("verbose") {
                set_level(Level::Debug);
            }
            let paths = a.positional();
            let [out] = paths else {
                anyhow::bail!(
                    "journal synth takes exactly one output file: \
                     arco journal synth <out.jsonl> [--records N]"
                );
            };
            let records = a.get_usize("records").map_err(anyhow::Error::msg)?.unwrap_or(1000);
            let seed = a.get_usize("seed").map_err(anyhow::Error::msg)?.unwrap_or(1) as u64;
            let model_name = a.get("model").unwrap();
            let model = model_by_name(model_name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown model '{model_name}' (known: {})",
                    model_names().join(", ")
                )
            })?;
            let backend_name = a.get("backend").unwrap();
            let kind = BackendKind::from_name(backend_name).ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown backend '{backend_name}' (known: {})",
                    BackendKind::known_names().join(", ")
                )
            })?;
            let backend = kind.build();
            let out = PathBuf::from(out);
            let started = std::time::Instant::now();
            let mut journal = eval::Journal::open(&out)?;
            let spaces: Vec<arco::space::ConfigSpace> = model
                .unique_tasks()
                .iter()
                .map(|(t, _)| arco::space::ConfigSpace::for_task(t, true))
                .collect();
            let mut rng = arco::util::rng::Pcg32::seeded(seed);
            let mut added = 0usize;
            let mut attempts = 0usize;
            while added < records {
                attempts += 1;
                if attempts > records.saturating_mul(20) + 1000 {
                    anyhow::bail!(
                        "journal synth: exhausted candidate points after {attempts} attempts \
                         ({added}/{records} records; the spaces may be too small)"
                    );
                }
                let space = &spaces[attempts % spaces.len()];
                let p = space.random_point(&mut rng);
                let key = eval::PointKey::of(space, &p);
                let m = backend.measure(space, &p);
                if journal.record(kind.name(), &key, &m) {
                    added += 1;
                    // Flush in slabs so a million-record synth holds a
                    // bounded tail in memory, exactly like a live shard.
                    if added % 10_000 == 0 {
                        journal.flush()?;
                    }
                }
            }
            journal.flush()?;
            let identities = journal.identities();
            drop(journal);
            println!(
                "journal synth: {}: {added} new record(s) ({identities} identities) across \
                 {} task(s) via {} in {:.2}s",
                out.display(),
                spaces.len(),
                kind.name(),
                started.elapsed().as_secs_f64()
            );
            Ok(())
        }
        Some("compact") => {
            let cli = Cli::new(
                "arco journal compact",
                "rewrite a journal in place, dropping duplicates and stale-fingerprint records",
            )
            .flag("verbose", Some('v'), "debug logging")
            .flag("help", Some('h'), "show help");
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                println!("\nusage: arco journal compact <file.jsonl>");
                return Ok(());
            }
            if a.has_flag("verbose") {
                set_level(Level::Debug);
            }
            let paths = a.positional();
            let [path] = paths else {
                anyhow::bail!("journal compact takes exactly one file: arco journal compact <file.jsonl>");
            };
            let path = PathBuf::from(path);
            let stats = eval::compact_journal(&path)?;
            println!(
                "journal compact: {}: read {} record(s), kept {}, dropped {} duplicate(s), \
                 {} malformed, {} stale-fingerprint; {}",
                path.display(),
                stats.read,
                stats.kept,
                stats.dropped_duplicates,
                stats.dropped_malformed,
                stats.dropped_stale,
                if stats.rewritten { "rewritten" } else { "already compact, untouched" }
            );
            Ok(())
        }
        Some("merge") => {
            let cli = Cli::new(
                "arco journal merge",
                "union fingerprint-identical measurement journals into one warm-start file",
            )
            .flag("verbose", Some('v'), "debug logging")
            .flag("help", Some('h'), "show help");
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                println!("\nusage: arco journal merge <out.jsonl> <in.jsonl...>");
                return Ok(());
            }
            if a.has_flag("verbose") {
                set_level(Level::Debug);
            }
            let paths = a.positional();
            if paths.len() < 2 {
                anyhow::bail!(
                    "journal merge needs an output and at least one input: \
                     arco journal merge <out.jsonl> <in.jsonl...>"
                );
            }
            let out = PathBuf::from(&paths[0]);
            let inputs: Vec<PathBuf> = paths[1..].iter().map(PathBuf::from).collect();
            let stats = eval::merge_journals(&out, &inputs)?;
            println!(
                "journal merge: {} <- {} input(s): read {} record(s), added {}, \
                 {} duplicate(s); output holds {} identities",
                out.display(),
                stats.inputs,
                stats.read,
                stats.added,
                stats.duplicates,
                stats.total
            );
            Ok(())
        }
        Some("--help") | Some("-h") | Some("help") | None => {
            print!("{sub_usage}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown journal subcommand '{other}'\n\n{sub_usage}"),
    }
}

/// `arco store stat|prune` — operator tooling for the shared measurement
/// store (`serve-measure --store <dir>`).
fn cmd_store(args: &[String]) -> anyhow::Result<()> {
    let sub_usage = "arco store <subcommand>\n\nsubcommands:\n  \
         stat <dir>                     segment count, bytes, identities, live locks\n  \
         prune <dir> [--budget-kib N]   delete oldest segments until the store fits \
         the byte budget (never the newest segment or a live writer's)\n";
    match args.first().map(String::as_str) {
        Some("stat") => {
            let cli = Cli::new("arco store stat", "read-only scan of a shared store directory")
                .flag("verbose", Some('v'), "debug logging")
                .flag("help", Some('h'), "show help");
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                println!("\nusage: arco store stat <dir>");
                return Ok(());
            }
            if a.has_flag("verbose") {
                set_level(Level::Debug);
            }
            let paths = a.positional();
            let [dir] = paths else {
                anyhow::bail!("store stat takes exactly one directory: arco store stat <dir>");
            };
            let dir = PathBuf::from(dir);
            let stats = eval::store_stat(&dir)?;
            println!(
                "store stat: {}: {} segment(s), {} bytes, {} identities, {} locked by live \
                 writers",
                dir.display(),
                stats.segments,
                stats.bytes,
                stats.identities,
                stats.locked
            );
            Ok(())
        }
        Some("prune") => {
            let cli = Cli::new(
                "arco store prune",
                "delete oldest store segments until the directory fits the byte budget",
            )
            .opt(
                "budget-kib",
                None,
                "byte budget in KiB",
                Some("262144"), // = StoreConfig::DEFAULT_BUDGET_BYTES
            )
            .flag("verbose", Some('v'), "debug logging")
            .flag("help", Some('h'), "show help");
            let a = cli.parse(&args[1..]).map_err(anyhow::Error::msg)?;
            if a.has_flag("help") {
                print!("{}", cli.usage());
                println!("\nusage: arco store prune <dir> [--budget-kib N]");
                return Ok(());
            }
            if a.has_flag("verbose") {
                set_level(Level::Debug);
            }
            let paths = a.positional();
            let [dir] = paths else {
                anyhow::bail!(
                    "store prune takes exactly one directory: \
                     arco store prune <dir> [--budget-kib N]"
                );
            };
            let dir = PathBuf::from(dir);
            let budget = a
                .get_u64("budget-kib")
                .map_err(anyhow::Error::msg)?
                .unwrap_or(eval::StoreConfig::DEFAULT_BUDGET_BYTES / 1024)
                .saturating_mul(1024)
                .max(1);
            let stats = eval::prune_store(&dir, budget)?;
            println!(
                "store prune: {}: {} of {} segment(s) deleted, {} -> {} bytes (budget {}), \
                 {} kept by live writers",
                dir.display(),
                stats.deleted,
                stats.segments_before,
                stats.bytes_before,
                stats.bytes_after,
                budget,
                stats.locked_kept
            );
            Ok(())
        }
        Some("--help") | Some("-h") | Some("help") | None => {
            print!("{sub_usage}");
            Ok(())
        }
        Some(other) => anyhow::bail!("unknown store subcommand '{other}'\n\n{sub_usage}"),
    }
}

fn cmd_info() -> anyhow::Result<()> {
    println!("arco {} — three-layer build info", env!("CARGO_PKG_VERSION"));
    let dir = arco::runtime::manifest::artifacts_dir();
    match arco::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts: {} ({} entry points)", dir.display(), m.artifact_files.len());
            for (name, file) in &m.artifact_files {
                println!("  {name:<16} {file}");
            }
            match arco::runtime::Engine::load(&dir) {
                Ok(e) => println!("backend: xla ({})", e.platform()),
                Err(e) => println!("backend: native (engine failed: {e})"),
            }
        }
        Err(e) => {
            println!("artifacts: not available ({e})");
            println!("backend: native (run `make artifacts`)");
        }
    }
    println!("simulator: VTA++ cycle model, default {:?}", arco::vta::VtaConfig::default());
    println!("measurement fingerprint: {}", eval::Fingerprint::current().describe());
    println!(
        "measurement backends: {}, remote:host:port[,...] (select with --backend; \
         --journal persists measurements; `arco serve-measure` exposes a shard)",
        BackendKind::known_names().join(", ")
    );
    Ok(())
}
