//! Dense MLP with manual forward/backward.
//!
//! Architectures (matching §4.1 of the paper and the L2 JAX graphs):
//! - policy network: 1 hidden layer of 20 ReLU units, softmax head;
//! - value network: 3 hidden layers of 20 tanh units, scalar head.
//!
//! Parameters are held as (weight, bias) per layer and can be flattened
//! to/from a single `Vec<f32>` in a stable order — the same order the AOT
//! artifacts use, so native and XLA backends are interchangeable.

use super::tensor::Mat;
use crate::util::rng::Pcg32;

/// Per-layer activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Relu,
    Tanh,
    Linear,
}

fn act(a: Act, x: f32) -> f32 {
    match a {
        Act::Relu => x.max(0.0),
        Act::Tanh => x.tanh(),
        Act::Linear => x,
    }
}

/// Derivative given the *activated* output.
fn act_grad_from_out(a: Act, y: f32) -> f32 {
    match a {
        Act::Relu => {
            if y > 0.0 {
                1.0
            } else {
                0.0
            }
        }
        Act::Tanh => 1.0 - y * y,
        Act::Linear => 1.0,
    }
}

/// One dense layer.
#[derive(Debug, Clone)]
pub struct Dense {
    pub w: Mat, // (in, out)
    pub b: Vec<f32>,
    pub act: Act,
}

/// A stack of dense layers.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<Dense>,
}

/// Forward cache for backprop: activated outputs per layer.
#[derive(Debug, Clone)]
pub struct ForwardCache {
    /// `outs[0]` = input, `outs[i]` = output of layer i-1.
    pub outs: Vec<Mat>,
}

impl ForwardCache {
    pub fn output(&self) -> &Mat {
        self.outs.last().unwrap()
    }
}

/// Gradients matching `Mlp` layout.
#[derive(Debug, Clone)]
pub struct MlpGrads {
    pub dw: Vec<Mat>,
    pub db: Vec<Vec<f32>>,
}

impl Mlp {
    /// Build with given layer sizes and activations;
    /// `sizes = [in, h1, ..., out]`, `acts.len() == sizes.len()-1`.
    pub fn new(sizes: &[usize], acts: &[Act], rng: &mut Pcg32) -> Mlp {
        assert_eq!(acts.len(), sizes.len() - 1);
        let layers = sizes
            .windows(2)
            .zip(acts)
            .map(|(s, &a)| Dense {
                w: Mat::rand_init(s[0], s[1], rng),
                b: vec![0.0; s[1]],
                act: a,
            })
            .collect();
        Mlp { layers }
    }

    /// The paper's policy network: obs -> 20 ReLU -> logits.
    pub fn policy(obs_dim: usize, act_dim: usize, rng: &mut Pcg32) -> Mlp {
        Mlp::new(&[obs_dim, 20, act_dim], &[Act::Relu, Act::Linear], rng)
    }

    /// The paper's centralized value network: state -> 3x20 tanh -> scalar.
    pub fn value(state_dim: usize, rng: &mut Pcg32) -> Mlp {
        Mlp::new(
            &[state_dim, 20, 20, 20, 1],
            &[Act::Tanh, Act::Tanh, Act::Tanh, Act::Linear],
            rng,
        )
    }

    /// Forward pass over a batch (rows = samples).
    pub fn forward(&self, input: &Mat) -> ForwardCache {
        let mut outs = Vec::with_capacity(self.layers.len() + 1);
        outs.push(input.clone());
        for layer in &self.layers {
            let mut z = outs.last().unwrap().matmul(&layer.w);
            z.add_bias(&layer.b);
            outs.push(z.map(|x| act(layer.act, x)));
        }
        ForwardCache { outs }
    }

    /// Backward pass: `d_out` = dLoss/d(final activated output).
    /// Returns parameter grads and (discarded) input grads.
    pub fn backward(&self, cache: &ForwardCache, d_out: &Mat) -> MlpGrads {
        let mut dw = Vec::with_capacity(self.layers.len());
        let mut db = Vec::with_capacity(self.layers.len());
        let mut delta = d_out.clone();
        for (i, layer) in self.layers.iter().enumerate().rev() {
            // Through the activation.
            let y = &cache.outs[i + 1];
            let dz = Mat {
                rows: delta.rows,
                cols: delta.cols,
                data: delta
                    .data
                    .iter()
                    .zip(&y.data)
                    .map(|(&d, &yv)| d * act_grad_from_out(layer.act, yv))
                    .collect(),
            };
            // Parameter grads.
            dw.push(cache.outs[i].t_matmul(&dz));
            db.push(dz.col_sum());
            // Input grads for the next (lower) layer.
            if i > 0 {
                delta = dz.matmul_t(&layer.w);
            }
        }
        dw.reverse();
        db.reverse();
        MlpGrads { dw, db }
    }

    /// Total parameter count.
    pub fn num_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.data.len() + l.b.len()).sum()
    }

    /// Flatten parameters: per layer, weights (row-major) then bias.
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_params());
        for l in &self.layers {
            out.extend_from_slice(&l.w.data);
            out.extend_from_slice(&l.b);
        }
        out
    }

    /// Load parameters from a flat vector (inverse of [`flatten`]).
    pub fn unflatten(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.num_params(), "flat param size mismatch");
        let mut off = 0;
        for l in &mut self.layers {
            let wlen = l.w.data.len();
            l.w.data.copy_from_slice(&flat[off..off + wlen]);
            off += wlen;
            let blen = l.b.len();
            l.b.copy_from_slice(&flat[off..off + blen]);
            off += blen;
        }
    }

    /// Flatten gradients in the same order as [`flatten`].
    pub fn flatten_grads(grads: &MlpGrads) -> Vec<f32> {
        let mut out = Vec::new();
        for (dw, db) in grads.dw.iter().zip(&grads.db) {
            out.extend_from_slice(&dw.data);
            out.extend_from_slice(db);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finite_diff_check(mlp: &Mlp, input: &Mat, loss_of_out: impl Fn(&Mat) -> f32 + Copy) {
        // Analytic grads via backward with d_out from finite differences of
        // the loss wrt outputs... simpler: compare full param grads.
        let cache = mlp.forward(input);
        let out = cache.output().clone();
        // dLoss/dOut numerically.
        let mut d_out = Mat::zeros(out.rows, out.cols);
        let eps = 1e-3f32;
        for i in 0..out.data.len() {
            let mut plus = out.clone();
            plus.data[i] += eps;
            let mut minus = out.clone();
            minus.data[i] -= eps;
            d_out.data[i] = (loss_of_out(&plus) - loss_of_out(&minus)) / (2.0 * eps);
        }
        let grads = mlp.backward(&cache, &d_out);
        let flat_grads = Mlp::flatten_grads(&grads);

        // Numeric param grads.
        let flat = mlp.flatten();
        let mut mlp2 = mlp.clone();
        for pi in (0..flat.len()).step_by(7) {
            let mut fplus = flat.clone();
            fplus[pi] += eps;
            mlp2.unflatten(&fplus);
            let lp = loss_of_out(mlp2.forward(input).output());
            let mut fminus = flat.clone();
            fminus[pi] -= eps;
            mlp2.unflatten(&fminus);
            let lm = loss_of_out(mlp2.forward(input).output());
            let num = (lp - lm) / (2.0 * eps);
            let ana = flat_grads[pi];
            assert!(
                (num - ana).abs() < 2e-2 * (1.0 + num.abs().max(ana.abs())),
                "param {pi}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn backward_matches_finite_differences_relu() {
        let mut rng = Pcg32::seeded(42);
        let mlp = Mlp::new(&[4, 8, 3], &[Act::Relu, Act::Linear], &mut rng);
        let input = Mat::rand_init(5, 4, &mut rng);
        // Loss = sum of squares of outputs.
        finite_diff_check(&mlp, &input, |o| o.data.iter().map(|x| x * x).sum::<f32>());
    }

    #[test]
    fn backward_matches_finite_differences_tanh() {
        let mut rng = Pcg32::seeded(7);
        let mlp = Mlp::new(&[3, 6, 6, 1], &[Act::Tanh, Act::Tanh, Act::Linear], &mut rng);
        let input = Mat::rand_init(4, 3, &mut rng);
        finite_diff_check(&mlp, &input, |o| o.data.iter().sum::<f32>());
    }

    #[test]
    fn flatten_roundtrip() {
        let mut rng = Pcg32::seeded(3);
        let mlp = Mlp::policy(16, 27, &mut rng);
        let flat = mlp.flatten();
        assert_eq!(flat.len(), mlp.num_params());
        let mut mlp2 = Mlp::policy(16, 27, &mut rng);
        mlp2.unflatten(&flat);
        assert_eq!(mlp2.flatten(), flat);
    }

    #[test]
    fn policy_shapes() {
        let mut rng = Pcg32::seeded(1);
        let p = Mlp::policy(16, 27, &mut rng);
        // (16*20 + 20) + (20*27 + 27) = 340 + 567 = 907
        assert_eq!(p.num_params(), 907);
        let out = p.forward(&Mat::zeros(8, 16));
        assert_eq!((out.output().rows, out.output().cols), (8, 27));
    }

    #[test]
    fn value_shapes() {
        let mut rng = Pcg32::seeded(1);
        let v = Mlp::value(24, &mut rng);
        let out = v.forward(&Mat::zeros(8, 24));
        assert_eq!((out.output().rows, out.output().cols), (8, 1));
    }

    #[test]
    fn training_reduces_loss() {
        // One gradient-descent loop on a toy regression target.
        let mut rng = Pcg32::seeded(11);
        let mut mlp = Mlp::new(&[2, 16, 1], &[Act::Tanh, Act::Linear], &mut rng);
        let x = Mat::rand_init(64, 2, &mut rng);
        let target: Vec<f32> = (0..64).map(|i| x.at(i, 0) * 2.0 - x.at(i, 1)).collect();
        let loss = |out: &Mat| -> f32 {
            out.data.iter().zip(&target).map(|(o, t)| (o - t) * (o - t)).sum::<f32>()
                / target.len() as f32
        };
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..200 {
            let cache = mlp.forward(&x);
            let out = cache.output();
            last = loss(out);
            first.get_or_insert(last);
            let d_out = Mat {
                rows: out.rows,
                cols: out.cols,
                data: out
                    .data
                    .iter()
                    .zip(&target)
                    .map(|(o, t)| 2.0 * (o - t) / target.len() as f32)
                    .collect(),
            };
            let grads = mlp.backward(&cache, &d_out);
            // SGD step.
            for (l, (dw, db)) in mlp.layers.iter_mut().zip(grads.dw.iter().zip(&grads.db)) {
                for (w, g) in l.w.data.iter_mut().zip(&dw.data) {
                    *w -= 0.1 * g;
                }
                for (b, g) in l.b.iter_mut().zip(db) {
                    *b -= 0.1 * g;
                }
            }
        }
        assert!(last < first.unwrap() * 0.1, "loss {first:?} -> {last}");
    }
}
