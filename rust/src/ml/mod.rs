//! Native neural-network substrate: f32 matrices, dense MLPs with manual
//! backprop, Adam, and the PPO/MAPPO math.
//!
//! This mirrors the L2 JAX graphs exactly (same architectures, same
//! parameter flattening order) so the MARL module can run on either the
//! AOT/XLA backend or this native one, and parity tests can compare them.

pub mod adam;
pub mod mlp;
pub mod ppo;
pub mod tensor;

pub use adam::{clip_grad_norm, Adam, AdamParams};
pub use mlp::{Act, ForwardCache, Mlp, MlpGrads};
pub use tensor::Mat;
