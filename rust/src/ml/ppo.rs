//! PPO / MAPPO math (§2.2, Eqs. 1–3): masked categorical policies, GAE, the
//! clipped surrogate objective and its gradient w.r.t. logits.
//!
//! These functions are the *native mirror* of the L2 JAX train-step graph;
//! the MARL exploration module can run on either backend and the parity
//! tests hold them to the same numbers.

use super::tensor::Mat;
use crate::util::rng::Pcg32;

/// Masked log-softmax over each row. `mask[j] = 1.0` keeps action j,
/// `0.0` forbids it (logit treated as -inf).
pub fn masked_log_softmax(logits: &Mat, mask: &[f32]) -> Mat {
    assert_eq!(logits.cols, mask.len());
    let mut out = Mat::zeros(logits.rows, logits.cols);
    for r in 0..logits.rows {
        let row = logits.row(r);
        let mut maxv = f32::NEG_INFINITY;
        for (j, &m) in mask.iter().enumerate() {
            if m > 0.0 {
                maxv = maxv.max(row[j]);
            }
        }
        let mut sum = 0.0f32;
        for (j, &m) in mask.iter().enumerate() {
            if m > 0.0 {
                sum += (row[j] - maxv).exp();
            }
        }
        let log_z = maxv + sum.ln();
        for j in 0..logits.cols {
            *out.at_mut(r, j) = if mask[j] > 0.0 { row[j] - log_z } else { f32::NEG_INFINITY };
        }
    }
    out
}

/// Masked softmax probabilities per row.
pub fn masked_softmax(logits: &Mat, mask: &[f32]) -> Mat {
    let lp = masked_log_softmax(logits, mask);
    lp.map(|x| if x.is_finite() { x.exp() } else { 0.0 })
}

/// Sample one action per row from masked probabilities.
pub fn sample_actions(probs: &Mat, rng: &mut Pcg32) -> Vec<usize> {
    (0..probs.rows)
        .map(|r| {
            let row = probs.row(r);
            let w: Vec<f64> = row.iter().map(|&p| p as f64).collect();
            rng.gen_weighted(&w)
        })
        .collect()
}

/// Per-row entropy of masked probabilities.
pub fn entropy(probs: &Mat) -> Vec<f32> {
    (0..probs.rows)
        .map(|r| {
            probs
                .row(r)
                .iter()
                .filter(|&&p| p > 0.0)
                .map(|&p| -p * p.ln())
                .sum()
        })
        .collect()
}

/// Generalized Advantage Estimation (Eq. 2).
///
/// `rewards[t]`, `values[t]` for t in 0..T, plus `bootstrap` = V(s_T).
/// Returns (advantages, returns) where returns[t] = advantages[t] + values[t].
pub fn gae(
    rewards: &[f32],
    values: &[f32],
    bootstrap: f32,
    gamma: f32,
    lambda: f32,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(rewards.len(), values.len());
    let t_len = rewards.len();
    let mut adv = vec![0.0f32; t_len];
    let mut acc = 0.0f32;
    for t in (0..t_len).rev() {
        let next_v = if t + 1 < t_len { values[t + 1] } else { bootstrap };
        let delta = rewards[t] + gamma * next_v - values[t];
        acc = delta + gamma * lambda * acc;
        adv[t] = acc;
    }
    let ret: Vec<f32> = adv.iter().zip(values).map(|(a, v)| a + v).collect();
    (adv, ret)
}

/// Normalize advantages to zero mean / unit std (standard MAPPO trick).
pub fn normalize_advantages(adv: &mut [f32]) {
    if adv.len() < 2 {
        return;
    }
    let n = adv.len() as f32;
    let mean = adv.iter().sum::<f32>() / n;
    let var = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / n;
    let std = var.sqrt().max(1e-6);
    for a in adv.iter_mut() {
        *a = (*a - mean) / std;
    }
}

/// PPO-clip surrogate loss (Eq. 3) and its gradient w.r.t. the logits.
///
/// Inputs per batch row: chosen `actions`, `old_logp`, `advantages`; plus
/// the shared action `mask`, clip `epsilon` and entropy bonus coefficient.
/// Returns (mean loss, dLoss/dlogits, mean entropy, clip fraction).
pub fn ppo_policy_loss_grad(
    logits: &Mat,
    mask: &[f32],
    actions: &[usize],
    old_logp: &[f32],
    advantages: &[f32],
    epsilon: f32,
    entropy_coef: f32,
) -> (f32, Mat, f32, f32) {
    let b = logits.rows;
    assert_eq!(actions.len(), b);
    assert_eq!(old_logp.len(), b);
    assert_eq!(advantages.len(), b);
    let logp = masked_log_softmax(logits, mask);
    let probs = logp.map(|x| if x.is_finite() { x.exp() } else { 0.0 });
    let ent = entropy(&probs);

    let mut d_logits = Mat::zeros(b, logits.cols);
    let mut loss_sum = 0.0f32;
    let mut ent_sum = 0.0f32;
    let mut clipped = 0usize;
    let inv_b = 1.0 / b as f32;

    for r in 0..b {
        let a = actions[r];
        debug_assert!(mask[a] > 0.0, "sampled a masked action");
        let lp = logp.at(r, a);
        let ratio = (lp - old_logp[r]).exp();
        let adv = advantages[r];
        let unclipped = ratio * adv;
        let clipped_ratio = ratio.clamp(1.0 - epsilon, 1.0 + epsilon);
        let clipped_obj = clipped_ratio * adv;
        // Surrogate: min of the two.
        let (obj, grad_active) = if unclipped <= clipped_obj {
            (unclipped, true)
        } else {
            (clipped_obj, false)
        };
        if !grad_active {
            clipped += 1;
        }
        loss_sum += -obj;
        ent_sum += ent[r];

        // d(-obj)/dlogits: only when the unclipped branch is active does the
        // ratio carry gradient; d ratio/d logp_a = ratio, and
        // d logp_a / d logits_j = (1[j==a] - p_j) for unmasked j.
        let coeff = if grad_active { -ratio * adv * inv_b } else { 0.0 };
        for j in 0..logits.cols {
            if mask[j] <= 0.0 {
                continue;
            }
            let p = probs.at(r, j);
            let indicator = if j == a { 1.0 } else { 0.0 };
            let mut g = coeff * (indicator - p);
            // Entropy bonus: d(-c*H)/dlogits_j = c * p_j * (log p_j + H).
            if entropy_coef != 0.0 && p > 0.0 {
                g += entropy_coef * inv_b * p * (p.ln() + ent[r]);
            }
            *d_logits.at_mut(r, j) += g;
        }
    }
    let mean_loss = loss_sum * inv_b - entropy_coef * ent_sum * inv_b;
    (mean_loss, d_logits, ent_sum * inv_b, clipped as f32 * inv_b)
}

/// Critic MSE loss (Eq. 1) and gradient w.r.t. predictions.
pub fn value_loss_grad(pred: &Mat, targets: &[f32]) -> (f32, Mat) {
    assert_eq!(pred.cols, 1);
    assert_eq!(pred.rows, targets.len());
    let b = pred.rows as f32;
    let mut d = Mat::zeros(pred.rows, 1);
    let mut loss = 0.0f32;
    for r in 0..pred.rows {
        let err = pred.at(r, 0) - targets[r];
        loss += err * err;
        *d.at_mut(r, 0) = 2.0 * err / b;
    }
    (loss / b, d)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        Mat::from_vec(rows, cols, data)
    }

    #[test]
    fn masked_softmax_ignores_masked() {
        let logits = mat(1, 3, vec![5.0, 100.0, 5.0]);
        let mask = vec![1.0, 0.0, 1.0];
        let p = masked_softmax(&logits, &mask);
        assert_eq!(p.at(0, 1), 0.0);
        assert!((p.at(0, 0) - 0.5).abs() < 1e-6);
        assert!((p.at(0, 2) - 0.5).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_normalizes() {
        let logits = mat(2, 4, vec![1.0, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0]);
        let mask = vec![1.0; 4];
        let lp = masked_log_softmax(&logits, &mask);
        for r in 0..2 {
            let total: f32 = lp.row(r).iter().map(|x| x.exp()).sum();
            assert!((total - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn gae_known_values() {
        // Single step: adv = r + gamma*V' - V.
        let (adv, ret) = gae(&[1.0], &[0.5], 0.25, 0.9, 0.95);
        assert!((adv[0] - (1.0 + 0.9 * 0.25 - 0.5)).abs() < 1e-6);
        assert!((ret[0] - (adv[0] + 0.5)).abs() < 1e-6);
    }

    #[test]
    fn gae_discounts_backwards() {
        let rewards = vec![0.0, 0.0, 1.0];
        let values = vec![0.0, 0.0, 0.0];
        let (adv, _) = gae(&rewards, &values, 0.0, 0.9, 1.0);
        // adv[2] = 1, adv[1] = 0.9, adv[0] = 0.81
        assert!((adv[2] - 1.0).abs() < 1e-6);
        assert!((adv[1] - 0.9).abs() < 1e-6);
        assert!((adv[0] - 0.81).abs() < 1e-6);
    }

    #[test]
    fn normalize_makes_zero_mean_unit_std() {
        let mut adv = vec![1.0, 2.0, 3.0, 4.0];
        normalize_advantages(&mut adv);
        let mean: f32 = adv.iter().sum::<f32>() / 4.0;
        let var: f32 = adv.iter().map(|a| a * a).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-5);
        assert!((var - 1.0).abs() < 1e-4);
    }

    #[test]
    fn ppo_gradient_matches_finite_difference() {
        let logits = mat(3, 4, vec![0.1, 0.4, -0.2, 0.3, 1.0, -1.0, 0.5, 0.0, -0.3, 0.2, 0.1, 0.9]);
        let mask = vec![1.0, 1.0, 1.0, 0.0];
        let actions = vec![0usize, 2, 1];
        let advantages = vec![1.0f32, -0.5, 0.8];
        // old_logp from the same logits (ratio = 1 at theta_old).
        let lp = masked_log_softmax(&logits, &mask);
        let old_logp: Vec<f32> = actions.iter().enumerate().map(|(r, &a)| lp.at(r, a)).collect();

        let (_, d, _, _) =
            ppo_policy_loss_grad(&logits, &mask, &actions, &old_logp, &advantages, 0.2, 0.01);

        let eps = 1e-3f32;
        for idx in 0..logits.data.len() {
            if mask[idx % 4] == 0.0 {
                continue;
            }
            let mut lplus = logits.clone();
            lplus.data[idx] += eps;
            let mut lminus = logits.clone();
            lminus.data[idx] -= eps;
            let (fp, _, _, _) =
                ppo_policy_loss_grad(&lplus, &mask, &actions, &old_logp, &advantages, 0.2, 0.01);
            let (fm, _, _, _) =
                ppo_policy_loss_grad(&lminus, &mask, &actions, &old_logp, &advantages, 0.2, 0.01);
            let num = (fp - fm) / (2.0 * eps);
            let ana = d.data[idx];
            assert!(
                (num - ana).abs() < 5e-3,
                "logit {idx}: numeric {num} vs analytic {ana}"
            );
        }
    }

    #[test]
    fn clip_fraction_detects_large_ratios() {
        let logits = mat(1, 2, vec![5.0, -5.0]);
        let mask = vec![1.0, 1.0];
        // Old policy put low prob on action 0 -> huge ratio, positive adv
        // -> clipped branch active.
        let (_, d, _, clip_frac) =
            ppo_policy_loss_grad(&logits, &mask, &[0], &[-3.0], &[1.0], 0.2, 0.0);
        assert_eq!(clip_frac, 1.0);
        // Clipped: no policy gradient.
        assert!(d.data.iter().all(|&g| g.abs() < 1e-9));
    }

    #[test]
    fn value_loss_gradient() {
        let pred = mat(2, 1, vec![1.0, 3.0]);
        let (loss, d) = value_loss_grad(&pred, &[0.0, 3.0]);
        assert!((loss - 0.5).abs() < 1e-6); // (1 + 0)/2
        assert!((d.at(0, 0) - 1.0).abs() < 1e-6); // 2*1/2
        assert_eq!(d.at(1, 0), 0.0);
    }

    #[test]
    fn sampling_respects_mask() {
        let logits = mat(1, 3, vec![0.0, 0.0, 0.0]);
        let mask = vec![1.0, 0.0, 1.0];
        let p = masked_softmax(&logits, &mask);
        let mut rng = Pcg32::seeded(5);
        for _ in 0..200 {
            let a = sample_actions(&p, &mut rng)[0];
            assert_ne!(a, 1);
        }
    }

    #[test]
    fn entropy_max_for_uniform() {
        let probs = mat(1, 4, vec![0.25; 4]);
        let e = entropy(&probs)[0];
        assert!((e - (4.0f32).ln()).abs() < 1e-5);
    }
}
