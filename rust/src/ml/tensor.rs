//! Minimal row-major f32 matrix used by the native MLP mirror.
//!
//! This is deliberately small: the MARL networks are 20-neuron MLPs, so the
//! native path needs correctness and predictable memory layout, not BLAS.
//! (The XLA runtime path executes the same math from AOT-compiled HLO; see
//! `runtime::` and the parity tests.)

/// Row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    /// He-style scaled uniform init (matches the python-side initializer).
    pub fn rand_init(rows: usize, cols: usize, rng: &mut crate::util::rng::Pcg32) -> Mat {
        let scale = (2.0 / rows as f64).sqrt() as f32;
        let data =
            (0..rows * cols).map(|_| (rng.gen_f32() * 2.0 - 1.0) * scale).collect();
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self @ other` — (m,k) x (k,n) -> (m,n).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.at(i, p);
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[p * n..(p + 1) * n];
                let crow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// `self^T @ other` without materializing the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul shape mismatch");
        let (k, m, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for p in 0..k {
            for i in 0..m {
                let a = self.at(p, i);
                if a == 0.0 {
                    continue;
                }
                let orow = &other.data[p * n..(p + 1) * n];
                let crow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    crow[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// `self @ other^T`.
    pub fn matmul_t(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_t shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            for j in 0..n {
                let brow = other.row(j);
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += arow[p] * brow[p];
                }
                *out.at_mut(i, j) = acc;
            }
        }
        out
    }

    /// Add a bias row-vector to every row.
    pub fn add_bias(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            let row = &mut self.data[r * self.cols..(r + 1) * self.cols];
            for (x, b) in row.iter_mut().zip(bias) {
                *x += b;
            }
        }
    }

    /// Column-wise sum (for bias gradients).
    pub fn col_sum(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (o, x) in out.iter_mut().zip(self.row(r)) {
                *o += x;
            }
        }
        out
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Element-wise product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a * b).collect(),
        }
    }

    pub fn scale(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Mat::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let c = a.t_matmul(&b); // (2,3)x(3,2) = (2,2)
        // a^T = [[1,3,5],[2,4,6]]; a^T b = [[1+0+5, 0+3+5],[2+0+6, 0+4+6]]
        assert_eq!(c.data, vec![6.0, 8.0, 8.0, 10.0]);
    }

    #[test]
    fn matmul_t_matches() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Mat::from_vec(2, 3, vec![1.0, 1.0, 1.0, 0.0, 1.0, 0.0]);
        let c = a.matmul_t(&b); // (2,3)x(3,2) = (2,2)
        assert_eq!(c.data, vec![6.0, 2.0, 15.0, 5.0]);
    }

    #[test]
    fn bias_and_colsum() {
        let mut a = Mat::zeros(2, 3);
        a.add_bias(&[1.0, 2.0, 3.0]);
        assert_eq!(a.col_sum(), vec![2.0, 4.0, 6.0]);
    }

    #[test]
    fn hadamard_and_map() {
        let a = Mat::from_vec(1, 3, vec![1.0, -2.0, 3.0]);
        let b = Mat::from_vec(1, 3, vec![2.0, 2.0, 2.0]);
        assert_eq!(a.hadamard(&b).data, vec![2.0, -4.0, 6.0]);
        assert_eq!(a.map(|x| x.max(0.0)).data, vec![1.0, 0.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
