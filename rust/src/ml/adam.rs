//! Adam optimizer over flat parameter vectors.

/// Adam hyper-parameters (MAPPO defaults from Yu et al., 2022).
#[derive(Debug, Clone, Copy)]
pub struct AdamParams {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamParams {
    fn default() -> Self {
        AdamParams { lr: 5e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

/// Optimizer state for one parameter vector.
#[derive(Debug, Clone)]
pub struct Adam {
    pub params: AdamParams,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u32,
}

impl Adam {
    pub fn new(n: usize, params: AdamParams) -> Adam {
        Adam { params, m: vec![0.0; n], v: vec![0.0; n], t: 0 }
    }

    /// In-place parameter update given gradients.
    pub fn step(&mut self, theta: &mut [f32], grads: &[f32]) {
        assert_eq!(theta.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let p = self.params;
        let bc1 = 1.0 - p.beta1.powi(self.t as i32);
        let bc2 = 1.0 - p.beta2.powi(self.t as i32);
        for i in 0..theta.len() {
            let g = grads[i];
            self.m[i] = p.beta1 * self.m[i] + (1.0 - p.beta1) * g;
            self.v[i] = p.beta2 * self.v[i] + (1.0 - p.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            theta[i] -= p.lr * mhat / (vhat.sqrt() + p.eps);
        }
    }

    pub fn steps_taken(&self) -> u32 {
        self.t
    }

    /// Restore optimizer state from flat (m, v, t) — used to round-trip
    /// state through the AOT train-step interface.
    pub fn restore_state(&mut self, m: &[f32], v: &[f32], t: u32) {
        assert_eq!(m.len(), self.m.len());
        assert_eq!(v.len(), self.v.len());
        self.m.copy_from_slice(m);
        self.v.copy_from_slice(v);
        self.t = t;
    }

    /// Expose optimizer state as flat (m, v, t).
    pub fn state(&self) -> (&[f32], &[f32], u32) {
        (&self.m, &self.v, self.t)
    }
}

/// Global-norm gradient clipping (MAPPO uses max_grad_norm=10).
pub fn clip_grad_norm(grads: &mut [f32], max_norm: f32) -> f32 {
    let norm = grads.iter().map(|g| (*g as f64) * (*g as f64)).sum::<f64>().sqrt() as f32;
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            *g *= scale;
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_minimizes_quadratic() {
        // f(x) = (x-3)^2 in each coordinate.
        let mut theta = vec![0.0f32; 4];
        let mut opt = Adam::new(4, AdamParams { lr: 0.1, ..Default::default() });
        for _ in 0..500 {
            let grads: Vec<f32> = theta.iter().map(|&x| 2.0 * (x - 3.0)).collect();
            opt.step(&mut theta, &grads);
        }
        for x in theta {
            assert!((x - 3.0).abs() < 1e-2, "{x}");
        }
    }

    #[test]
    fn first_step_magnitude_is_lr() {
        // Adam's debiased first step is ~lr in the gradient direction.
        let mut theta = vec![0.0f32];
        let mut opt = Adam::new(1, AdamParams { lr: 0.01, ..Default::default() });
        opt.step(&mut theta, &[5.0]);
        assert!((theta[0] + 0.01).abs() < 1e-4, "{}", theta[0]);
    }

    #[test]
    fn clip_reduces_large_norms() {
        let mut g = vec![3.0f32, 4.0];
        let norm = clip_grad_norm(&mut g, 1.0);
        assert!((norm - 5.0).abs() < 1e-6);
        let new_norm = (g[0] * g[0] + g[1] * g[1]).sqrt();
        assert!((new_norm - 1.0).abs() < 1e-6);
    }

    #[test]
    fn clip_leaves_small_norms() {
        let mut g = vec![0.3f32, 0.4];
        clip_grad_norm(&mut g, 1.0);
        assert_eq!(g, vec![0.3, 0.4]);
    }
}
