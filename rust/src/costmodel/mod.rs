//! Surrogate cost models.
//!
//! AutoTVM's tuner trains an XGBoost regressor (`xgb-reg` mode, Table 5) on
//! measured configurations and uses it to rank candidates instead of paying
//! for a hardware measurement. XGBoost is not available offline, so
//! [`gbt`] implements gradient-boosted regression trees from scratch with
//! the same role: squared-error boosting over depth-limited regression
//! trees with greedy exact splits.

pub mod features;
pub mod gbt;

pub use features::featurize;
pub use gbt::{Gbt, GbtParams};

/// A trainable regression surrogate over feature vectors.
pub trait CostModel {
    /// Fit from scratch on (features, fitness) pairs.
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]);
    /// Predict fitness for one feature vector.
    fn predict(&self, x: &[f64]) -> f64;
    /// Predict a batch.
    fn predict_batch(&self, xs: &[Vec<f64>]) -> Vec<f64> {
        xs.iter().map(|x| self.predict(x)).collect()
    }
    /// True once `fit` has seen data.
    fn is_trained(&self) -> bool;
}
