//! Gradient-boosted regression trees (the offline stand-in for XGBoost's
//! `xgb-reg` mode).
//!
//! Squared-error boosting: each round fits a depth-limited CART tree to the
//! current residuals by greedy exact split search, then shrinks its
//! contribution by the learning rate. Matches what AutoTVM needs from its
//! cost model: fast refits on ≤1000 rows, monotone ranking quality, and
//! millisecond-scale batch prediction over thousands of candidates.

use super::CostModel;

/// Boosting hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct GbtParams {
    /// Number of boosting rounds.
    pub n_trees: usize,
    /// Maximum tree depth.
    pub max_depth: usize,
    /// Shrinkage per round.
    pub learning_rate: f64,
    /// Minimum samples in a leaf.
    pub min_leaf: usize,
    /// L2 regularization on leaf values.
    pub lambda: f64,
}

impl Default for GbtParams {
    fn default() -> Self {
        GbtParams { n_trees: 64, max_depth: 4, learning_rate: 0.3, min_leaf: 2, lambda: 1.0 }
    }
}

/// Flat-array binary tree node.
#[derive(Debug, Clone)]
enum Node {
    Leaf { value: f64 },
    Split { feature: usize, threshold: f64, left: usize, right: usize },
}

/// One regression tree.
#[derive(Debug, Clone)]
struct Tree {
    nodes: Vec<Node>,
}

impl Tree {
    fn predict(&self, x: &[f64]) -> f64 {
        let mut i = 0usize;
        loop {
            match &self.nodes[i] {
                Node::Leaf { value } => return *value,
                Node::Split { feature, threshold, left, right } => {
                    i = if x[*feature] <= *threshold { *left } else { *right };
                }
            }
        }
    }
}

/// The boosted ensemble.
#[derive(Debug, Clone)]
pub struct Gbt {
    params: GbtParams,
    base: f64,
    trees: Vec<Tree>,
}

impl Gbt {
    pub fn new(params: GbtParams) -> Self {
        Gbt { params, base: 0.0, trees: Vec::new() }
    }

    pub fn num_trees(&self) -> usize {
        self.trees.len()
    }
}

impl Default for Gbt {
    fn default() -> Self {
        Gbt::new(GbtParams::default())
    }
}

/// Pre-sorted feature columns, computed once per `fit` and reused by every
/// tree and node: feature order never changes across boosting rounds, so
/// split search walks the global order with a node-membership mask instead
/// of re-sorting each node (EXPERIMENTS.md §Perf, L3 item 2 — ~5x on fit).
struct SortedCols(Vec<Vec<u32>>);

impl SortedCols {
    fn build(x: &[Vec<f64>]) -> SortedCols {
        let n_features = x[0].len();
        let cols = (0..n_features)
            .map(|f| {
                let mut idx: Vec<u32> = (0..x.len() as u32).collect();
                idx.sort_by(|&a, &b| {
                    x[a as usize][f]
                        .partial_cmp(&x[b as usize][f])
                        .unwrap_or(std::cmp::Ordering::Equal)
                });
                idx
            })
            .collect();
        SortedCols(cols)
    }
}

/// Best split for one node: (feature, threshold, gain).
fn best_split(
    x: &[Vec<f64>],
    residual: &[f64],
    rows: &[usize],
    in_node: &[bool],
    sorted: &SortedCols,
    lambda: f64,
    min_leaf: usize,
) -> Option<(usize, f64, f64)> {
    if rows.len() < 2 * min_leaf {
        return None;
    }
    let n_features = x[rows[0]].len();
    let total_sum: f64 = rows.iter().map(|&r| residual[r]).sum();
    let total_n = rows.len() as f64;
    let parent_score = total_sum * total_sum / (total_n + lambda);

    let mut best: Option<(usize, f64, f64)> = None;
    for f in 0..n_features {
        let mut left_sum = 0.0;
        let mut left_n = 0usize;
        let mut prev: Option<f64> = None;
        for &ri in &sorted.0[f] {
            let r = ri as usize;
            if !in_node[r] {
                continue;
            }
            let v = x[r][f];
            // Evaluate the split *between* the previous member and this one.
            if let Some(pv) = prev {
                if pv != v
                    && left_n >= min_leaf
                    && rows.len() - left_n >= min_leaf
                {
                    let right_sum = total_sum - left_sum;
                    let right_n = total_n - left_n as f64;
                    let gain = left_sum * left_sum / (left_n as f64 + lambda)
                        + right_sum * right_sum / (right_n + lambda)
                        - parent_score;
                    if best.map_or(true, |(_, _, g)| gain > g) && gain > 1e-12 {
                        best = Some((f, 0.5 * (pv + v), gain));
                    }
                }
            }
            left_sum += residual[r];
            left_n += 1;
            prev = Some(v);
        }
    }
    best
}

/// Recursively grow a tree on `rows`, returning the root node index.
/// `in_node` is the membership mask of `rows` (kept in sync by the caller).
#[allow(clippy::too_many_arguments)]
fn grow(
    nodes: &mut Vec<Node>,
    x: &[Vec<f64>],
    residual: &[f64],
    rows: Vec<usize>,
    in_node: &mut [bool],
    sorted: &SortedCols,
    depth: usize,
    p: &GbtParams,
) -> usize {
    let sum: f64 = rows.iter().map(|&r| residual[r]).sum();
    let leaf_value = sum / (rows.len() as f64 + p.lambda);
    if depth >= p.max_depth {
        nodes.push(Node::Leaf { value: leaf_value });
        return nodes.len() - 1;
    }
    match best_split(x, residual, &rows, in_node, sorted, p.lambda, p.min_leaf) {
        None => {
            nodes.push(Node::Leaf { value: leaf_value });
            nodes.len() - 1
        }
        Some((feature, threshold, _gain)) => {
            let (lrows, rrows): (Vec<usize>, Vec<usize>) =
                rows.into_iter().partition(|&r| x[r][feature] <= threshold);
            let idx = nodes.len();
            nodes.push(Node::Leaf { value: 0.0 }); // placeholder
            // Recurse left with only left rows marked, then right.
            for &r in &rrows {
                in_node[r] = false;
            }
            let left = grow(nodes, x, residual, lrows.clone(), in_node, sorted, depth + 1, p);
            for &r in &lrows {
                in_node[r] = false;
            }
            for &r in &rrows {
                in_node[r] = true;
            }
            let right = grow(nodes, x, residual, rrows.clone(), in_node, sorted, depth + 1, p);
            // Restore the full node membership for the caller.
            for &r in &lrows {
                in_node[r] = true;
            }
            nodes[idx] = Node::Split { feature, threshold, left, right };
            idx
        }
    }
}

impl CostModel for Gbt {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        self.trees.clear();
        if x.is_empty() {
            self.base = 0.0;
            return;
        }
        self.base = y.iter().sum::<f64>() / y.len() as f64;
        let mut pred = vec![self.base; y.len()];
        let all_rows: Vec<usize> = (0..x.len()).collect();
        let sorted = SortedCols::build(x);
        let mut in_node = vec![true; x.len()];
        for _round in 0..self.params.n_trees {
            let residual: Vec<f64> = y.iter().zip(&pred).map(|(t, p)| t - p).collect();
            let mut nodes = Vec::new();
            in_node.fill(true);
            let root = grow(
                &mut nodes,
                x,
                &residual,
                all_rows.clone(),
                &mut in_node,
                &sorted,
                0,
                &self.params,
            );
            debug_assert_eq!(root, 0);
            let tree = Tree { nodes };
            // Early stop: a single pure leaf adds ~nothing.
            let lr = self.params.learning_rate;
            let mut improved = false;
            for (i, xi) in x.iter().enumerate() {
                let delta = lr * tree.predict(xi);
                if delta.abs() > 1e-12 {
                    improved = true;
                }
                pred[i] += delta;
            }
            self.trees.push(tree);
            if !improved {
                break;
            }
        }
    }

    fn predict(&self, x: &[f64]) -> f64 {
        let mut p = self.base;
        for t in &self.trees {
            p += self.params.learning_rate * t.predict(x);
        }
        p
    }

    fn is_trained(&self) -> bool {
        !self.trees.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::util::stats::pearson;

    fn make_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        // y = 3*x0 - 2*x1 + x2*x0 + noise — nonlinear enough to need trees.
        let mut rng = Pcg32::seeded(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let row = vec![rng.gen_f64(), rng.gen_f64(), rng.gen_f64(), rng.gen_f64()];
            let t = 3.0 * row[0] - 2.0 * row[1] + row[2] * row[0] + 0.01 * rng.gen_normal();
            x.push(row);
            y.push(t);
        }
        (x, y)
    }

    #[test]
    fn fits_training_data_well() {
        let (x, y) = make_data(300, 1);
        let mut m = Gbt::default();
        m.fit(&x, &y);
        let preds = m.predict_batch(&x);
        let corr = pearson(&preds, &y);
        assert!(corr > 0.97, "train corr {corr}");
    }

    #[test]
    fn generalizes_to_heldout() {
        let (xtr, ytr) = make_data(400, 2);
        let (xte, yte) = make_data(100, 3);
        let mut m = Gbt::default();
        m.fit(&xtr, &ytr);
        let preds = m.predict_batch(&xte);
        let corr = pearson(&preds, &yte);
        assert!(corr > 0.9, "test corr {corr}");
    }

    #[test]
    fn untrained_predicts_zero() {
        let m = Gbt::default();
        assert!(!m.is_trained());
        assert_eq!(m.predict(&[1.0, 2.0]), 0.0);
    }

    #[test]
    fn constant_target_learns_constant() {
        let x: Vec<Vec<f64>> = (0..50).map(|i| vec![i as f64]).collect();
        let y = vec![7.0; 50];
        let mut m = Gbt::default();
        m.fit(&x, &y);
        for xi in &x {
            assert!((m.predict(xi) - 7.0).abs() < 0.2, "{}", m.predict(xi));
        }
    }

    #[test]
    fn single_sample_is_safe() {
        let mut m = Gbt::default();
        m.fit(&[vec![1.0, 2.0]], &[5.0]);
        assert!((m.predict(&[1.0, 2.0]) - 5.0).abs() < 1.0);
    }

    #[test]
    fn empty_fit_is_safe() {
        let mut m = Gbt::default();
        m.fit(&[], &[]);
        assert_eq!(m.predict(&[0.0]), 0.0);
    }

    #[test]
    fn step_function_recovered() {
        // Pure axis-aligned structure: trees should nail it.
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 100.0]).collect();
        let y: Vec<f64> = x.iter().map(|r| if r[0] < 0.5 { 1.0 } else { -1.0 }).collect();
        let mut m = Gbt::default();
        m.fit(&x, &y);
        assert!(m.predict(&[0.2]) > 0.8);
        assert!(m.predict(&[0.8]) < -0.8);
    }

    #[test]
    fn ranking_quality_on_noisy_data() {
        // The tuner only needs ranking: top-predicted should be top-true.
        let (x, y) = make_data(500, 9);
        let mut m = Gbt::default();
        m.fit(&x, &y);
        let preds = m.predict_batch(&x);
        let mut idx: Vec<usize> = (0..x.len()).collect();
        idx.sort_by(|&a, &b| preds[b].partial_cmp(&preds[a]).unwrap());
        let top32: Vec<f64> = idx[..32].iter().map(|&i| y[i]).collect();
        let mean_top = crate::util::stats::mean(&top32);
        let mean_all = crate::util::stats::mean(&y);
        assert!(mean_top > mean_all + 0.5, "top32 {mean_top} vs all {mean_all}");
    }
}
