//! Feature extraction for the cost models.
//!
//! Mirrors AutoTVM's "knob" featurization plus a handful of cheap derived
//! features that encode *why* a configuration is fast or slow on VTA++
//! (array occupancy, buffer pressure, DMA-to-compute balance). All features
//! are O(1) to compute — no lowering or simulation involved.

use crate::space::{ConfigSpace, PointConfig};
use crate::vta::config::{ACC_BYTES, INP_BYTES, WGT_BYTES};

/// Number of features produced by [`featurize`].
pub const NUM_FEATURES: usize = 18;

/// Build the feature vector of one configuration point.
pub fn featurize(space: &ConfigSpace, point: &PointConfig) -> Vec<f64> {
    let (hw, sw) = space.decode(point);
    let t = &space.task;
    let oh = t.oh();
    let ow = t.ow();

    // Knob values, log2-scaled (they are powers of two / small ints).
    let lg = |v: usize| (v.max(1) as f64).log2();

    // Derived: array occupancy estimate along each blocked dimension.
    let occ_b = t.n as f64 / (((t.n + hw.batch - 1) / hw.batch) * hw.batch) as f64;
    let occ_ci = t.ci as f64 / (((t.ci + hw.block_in - 1) / hw.block_in) * hw.block_in) as f64;
    let occ_co = t.co as f64 / (((t.co + hw.block_out - 1) / hw.block_out) * hw.block_out) as f64;

    // Spatial tiling: tiles per plane and edge waste.
    let tiles_h = (oh + sw.tile_h - 1) / sw.tile_h;
    let tiles_w = (ow + sw.tile_w - 1) / sw.tile_w;
    let spatial_waste =
        1.0 - (oh * ow) as f64 / ((tiles_h * sw.tile_h) * (tiles_w * sw.tile_w)) as f64;

    // Buffer pressure: tile working set / capacity (can exceed 1 = invalid).
    let in_h = (sw.tile_h - 1) * t.stride + t.kh;
    let in_w = (sw.tile_w - 1) * t.stride + t.kw;
    let inp_tile = (hw.batch * in_h * in_w * hw.block_in * INP_BYTES) as f64;
    let wgt_tile = (hw.block_out * hw.block_in * t.kh * t.kw * WGT_BYTES) as f64;
    let acc_tile = (hw.batch * sw.tile_h * sw.tile_w * hw.block_out * ACC_BYTES) as f64;
    let inp_pressure = inp_tile / hw.inp_buf_bytes() as f64;
    let wgt_pressure = wgt_tile / hw.wgt_buf_bytes() as f64;
    let acc_pressure = acc_tile / hw.acc_buf_bytes() as f64;

    // Compute/DMA balance of one tile: uop cycles vs load beats.
    let tile_uops = (sw.tile_h * sw.tile_w * t.kh * t.kw) as f64;
    let tile_dma = (inp_tile + wgt_tile) / hw.dram_bytes_per_cycle as f64;
    let balance = tile_uops / (tile_uops + tile_dma);

    vec![
        lg(hw.batch),
        lg(hw.block_in),
        lg(hw.block_out),
        sw.h_threading as f64 - 1.0,
        sw.oc_threading as f64 - 1.0,
        lg(sw.tile_h),
        lg(sw.tile_w),
        occ_b,
        occ_ci,
        occ_co,
        (tiles_h * tiles_w) as f64 / (oh * ow) as f64, // tile granularity
        spatial_waste,
        inp_pressure.min(4.0),
        wgt_pressure.min(4.0),
        acc_pressure.min(4.0),
        balance,
        lg(hw.macs_per_cycle()),
        t.arithmetic_intensity().ln(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 56, 56, 128, 3, 3, 1, 1), true)
    }

    #[test]
    fn feature_count_is_stable() {
        let s = space();
        let f = featurize(&s, &s.default_point());
        assert_eq!(f.len(), NUM_FEATURES);
    }

    #[test]
    fn features_finite_for_whole_space_sample() {
        let s = space();
        let mut rng = Pcg32::seeded(17);
        for _ in 0..500 {
            let p = s.random_point(&mut rng);
            for (i, f) in featurize(&s, &p).iter().enumerate() {
                assert!(f.is_finite(), "feature {i} not finite for {p:?}");
            }
        }
    }

    #[test]
    fn distinct_points_distinct_features() {
        let s = space();
        let a = s.default_point();
        let mut b = a.clone();
        b.0[1] = (b.0[1] + 1) % s.knobs[1].len();
        assert_ne!(featurize(&s, &a), featurize(&s, &b));
    }

    #[test]
    fn occupancy_features_in_unit_range() {
        let s = space();
        let mut rng = Pcg32::seeded(23);
        for _ in 0..200 {
            let p = s.random_point(&mut rng);
            let f = featurize(&s, &p);
            for idx in 7..10 {
                assert!((0.0..=1.0).contains(&f[idx]), "occ feature {idx} = {}", f[idx]);
            }
        }
    }
}
