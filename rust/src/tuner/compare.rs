//! Framework comparison driver: tunes every unique task of a network with
//! each framework and aggregates end-to-end inference time, compilation
//! time and convergence traces — the data behind Fig. 5, Fig. 6, Fig. 7
//! and Table 6.

use super::strategy::Strategy;
use super::task_tuner::{tune_task_with, TaskTuneResult, TuneBudget};
use crate::baselines::{AutoTvm, Chameleon, RandomSearch};
use crate::baselines::autotvm::AutoTvmParams;
use crate::baselines::chameleon::ChameleonParams;
use crate::eval;
use crate::marl::strategy::{Arco, ArcoParams};
use crate::space::ConfigSpace;
use crate::workload::ModelSpec;

/// Frameworks under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Framework {
    AutoTvm,
    Chameleon,
    Arco,
    /// Ablations / sanity baselines.
    Random,
    /// ARCO with Confidence Sampling disabled (Fig. 4 "before").
    ArcoNoCs,
    /// ARCO with hardware knobs frozen (isolates the co-design gain).
    ArcoSwOnly,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::AutoTvm => "autotvm",
            Framework::Chameleon => "chameleon",
            Framework::Arco => "arco",
            Framework::Random => "random",
            Framework::ArcoNoCs => "arco-nocs",
            Framework::ArcoSwOnly => "arco-swonly",
        }
    }

    pub fn from_name(s: &str) -> Option<Framework> {
        Some(match s {
            "autotvm" => Framework::AutoTvm,
            "chameleon" => Framework::Chameleon,
            "arco" => Framework::Arco,
            "random" => Framework::Random,
            "arco-nocs" => Framework::ArcoNoCs,
            "arco-swonly" => Framework::ArcoSwOnly,
            _ => return None,
        })
    }

    /// The paper's three (Figs. 5-7, Table 6).
    pub fn paper_set() -> Vec<Framework> {
        vec![Framework::AutoTvm, Framework::Chameleon, Framework::Arco]
    }

    /// Does this framework explore hardware knobs?
    pub fn tunes_hardware(self) -> bool {
        matches!(self, Framework::Arco | Framework::ArcoNoCs)
    }

    /// Instantiate a strategy for one task space.
    pub fn build(self, space: ConfigSpace, quick: bool, seed: u64) -> Box<dyn Strategy> {
        match self {
            Framework::AutoTvm => {
                let p = if quick { AutoTvmParams::quick() } else { AutoTvmParams::default() };
                Box::new(AutoTvm::new(space, p, seed))
            }
            Framework::Chameleon => {
                let p = if quick { ChameleonParams::quick() } else { ChameleonParams::default() };
                Box::new(Chameleon::new(space, p, seed))
            }
            Framework::Arco | Framework::ArcoSwOnly => {
                let p = if quick { ArcoParams::quick() } else { ArcoParams::default() };
                Box::new(Arco::new(space, p, seed))
            }
            Framework::ArcoNoCs => {
                let mut p = if quick { ArcoParams::quick() } else { ArcoParams::default() };
                p.use_cs = false;
                Box::new(Arco::new(space, p, seed))
            }
            Framework::Random => Box::new(RandomSearch::new(space, seed)),
        }
    }
}

/// Per-task outcome inside a model run.
#[derive(Debug, Clone)]
pub struct TaskOutcome {
    pub task_id: String,
    pub weight: usize,
    pub result: TaskTuneResult,
}

/// One (framework, model) outcome.
#[derive(Debug, Clone)]
pub struct ModelOutcome {
    pub framework: Framework,
    pub model: String,
    pub tasks: Vec<TaskOutcome>,
    /// End-to-end mean inference time (s): Σ weight × best task runtime.
    pub inference_secs: f64,
    /// Total compilation time across tasks (s): search wall-clock plus the
    /// modeled hardware-measurement time (overhead + repeats x runtime per
    /// config) — the quantity the paper's Fig. 6 compares.
    pub compile_secs: f64,
    /// Search-only wall-clock (planner/learner compute, excl. measurements).
    pub search_secs: f64,
    /// Total hardware measurements spent.
    pub measurements: usize,
}

impl ModelOutcome {
    /// Throughput in inferences/second.
    pub fn throughput(&self) -> f64 {
        if self.inference_secs > 0.0 {
            1.0 / self.inference_secs
        } else {
            0.0
        }
    }
}

/// Full comparison report (all frameworks × one model).
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub model: String,
    pub outcomes: Vec<ModelOutcome>,
}

impl CompareReport {
    pub fn outcome(&self, f: Framework) -> Option<&ModelOutcome> {
        self.outcomes.iter().find(|o| o.framework == f)
    }

    /// Fig. 6's optimization-time metric: modeled time for `f` to reach
    /// AutoTVM's final per-task quality (time-to-parity), plus its own
    /// search compute. The paper benchmarks at "the same AutoTVM
    /// compilation duration"; time-to-parity is the inverse view of that
    /// protocol and is robust to frameworks with different space sizes.
    pub fn compile_secs_to_parity(&self, f: Framework) -> Option<f64> {
        let base = self.outcome(Framework::AutoTvm)?;
        let ours = self.outcome(f)?;
        let mut total = ours.search_secs;
        for t in &ours.tasks {
            let target = base
                .tasks
                .iter()
                .find(|b| b.task_id == t.task_id)
                .map(|b| b.result.best.gflops)
                .unwrap_or(0.0);
            total += t.result.modeled_secs_to_quality(target);
        }
        Some(total)
    }

    /// Throughput of `f` normalized to AutoTVM (Fig. 5's y-axis).
    pub fn throughput_vs_autotvm(&self, f: Framework) -> Option<f64> {
        let base = self.outcome(Framework::AutoTvm)?.throughput();
        let ours = self.outcome(f)?.throughput();
        if base > 0.0 {
            Some(ours / base)
        } else {
            None
        }
    }
}

/// Tune one model end-to-end with one framework, using a private default
/// measurement engine. Prefer [`tune_model_with`] with a shared engine when
/// running several frameworks or models: tasks repeated across frameworks
/// are then simulated once and served from the cache afterwards.
pub fn tune_model(
    framework: Framework,
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
) -> ModelOutcome {
    let engine = eval::Engine::vta_sim(budget.workers);
    tune_model_with(&engine, framework, model, budget, quick, seed)
}

/// Tune one model end-to-end with one framework through a shared engine.
pub fn tune_model_with(
    engine: &eval::Engine,
    framework: Framework,
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
) -> ModelOutcome {
    let mut tasks = Vec::new();
    let mut inference_secs = 0.0f64;
    let mut compile_secs = 0.0f64;
    let mut search_secs = 0.0f64;
    let mut measurements = 0usize;
    for (i, (task, weight)) in model.unique_tasks().iter().enumerate() {
        let space = ConfigSpace::for_task(task, framework.tunes_hardware());
        let mut strategy = framework.build(space.clone(), quick, seed ^ (i as u64) << 32);
        let result = tune_task_with(engine, &space, strategy.as_mut(), budget);
        crate::log_info!(
            "compare",
            "{} {} task {}/{} {}: best {:.3e}s over {} measurements ({})",
            framework.name(),
            model.name,
            i + 1,
            model.unique_tasks().len(),
            task.short_id(),
            result.best.seconds,
            result.measurements,
            strategy.diag()
        );
        inference_secs += *weight as f64 * result.best.seconds;
        compile_secs += result.wall_secs + result.modeled_hw_secs;
        search_secs += result.wall_secs;
        measurements += result.measurements;
        tasks.push(TaskOutcome { task_id: task.short_id(), weight: *weight, result });
    }
    ModelOutcome {
        framework,
        model: model.name.to_string(),
        tasks,
        inference_secs,
        compile_secs,
        search_secs,
        measurements,
    }
}

/// Compare a set of frameworks on one model. All frameworks share one
/// measurement engine, so a configuration measured by one framework is a
/// cache hit for every later framework that plans it.
pub fn compare_frameworks(
    frameworks: &[Framework],
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
) -> CompareReport {
    let engine = eval::Engine::vta_sim(budget.workers);
    compare_frameworks_with(&engine, frameworks, model, budget, quick, seed)
}

/// [`compare_frameworks`] over a caller-provided engine (shared cache /
/// journal across models and processes).
pub fn compare_frameworks_with(
    engine: &eval::Engine,
    frameworks: &[Framework],
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
) -> CompareReport {
    let outcomes = frameworks
        .iter()
        .map(|&f| tune_model_with(engine, f, model, budget, quick, seed))
        .collect();
    crate::log_info!("compare", "{}: eval {}", model.name, engine.summary());
    CompareReport { model: model.name.to_string(), outcomes }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::model_by_name;

    fn tiny_budget() -> TuneBudget {
        TuneBudget { total_measurements: 48, batch: 16, workers: 2, ..Default::default() }
    }

    #[test]
    fn framework_names_roundtrip() {
        for f in [
            Framework::AutoTvm,
            Framework::Chameleon,
            Framework::Arco,
            Framework::Random,
            Framework::ArcoNoCs,
            Framework::ArcoSwOnly,
        ] {
            assert_eq!(Framework::from_name(f.name()), Some(f));
        }
        assert_eq!(Framework::from_name("nope"), None);
    }

    #[test]
    fn hardware_tuning_partition() {
        assert!(Framework::Arco.tunes_hardware());
        assert!(!Framework::AutoTvm.tunes_hardware());
        assert!(!Framework::Chameleon.tunes_hardware());
        assert!(!Framework::ArcoSwOnly.tunes_hardware());
    }

    #[test]
    fn tune_model_aggregates_weighted_inference_time() {
        // AlexNet is the smallest zoo model (5 tasks, weight 1 each).
        let model = model_by_name("alexnet").unwrap();
        let out = tune_model(Framework::Random, &model, tiny_budget(), true, 3);
        assert_eq!(out.tasks.len(), model.unique_tasks().len());
        let manual: f64 = out
            .tasks
            .iter()
            .map(|t| t.weight as f64 * t.result.best.seconds)
            .sum();
        assert!((out.inference_secs - manual).abs() < 1e-12);
        assert!(out.inference_secs.is_finite() && out.inference_secs > 0.0);
        // Budget is an upper bound: tiny layers (e.g. 13x13 planes with only
        // two tile candidates per dim) have spaces smaller than the budget
        // and exhaust early.
        for t in &out.tasks {
            assert!(t.result.measurements <= 48);
            assert!(t.result.measurements > 0);
        }
        assert!(out.measurements <= 48 * model.unique_tasks().len());
    }

    #[test]
    fn compare_report_normalizes_to_autotvm() {
        let model = model_by_name("alexnet").unwrap();
        let report = compare_frameworks(
            &[Framework::AutoTvm, Framework::Random],
            &model,
            tiny_budget(),
            true,
            5,
        );
        let rel = report.throughput_vs_autotvm(Framework::AutoTvm).unwrap();
        assert!((rel - 1.0).abs() < 1e-12);
        assert!(report.throughput_vs_autotvm(Framework::Random).unwrap() > 0.0);
    }
}
