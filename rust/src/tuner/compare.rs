//! Framework comparison driver: tunes every unique task of a network with
//! each framework and aggregates end-to-end inference time, compilation
//! time and convergence traces — the data behind Fig. 5, Fig. 6, Fig. 7
//! and Table 6.
//!
//! Two driver shapes share the same per-job code:
//!
//! - the classic serial driver (frameworks outer, tasks inner), and
//! - a concurrent multi-tenant driver ([`DriverOptions::concurrent`]):
//!   every (framework, task) job runs on its own `util::pool` thread, all
//!   jobs measure through ONE shared engine/fleet, a FIFO
//!   [`Dispatcher`] interleaves their batches so no framework monopolizes
//!   the shards, and (with [`DriverOptions::shared_budget`]) a
//!   [`BudgetLedger`] enforces the paper's equal-budget protocol —
//!   "measure once, charge everyone". Deterministic backends make the
//!   concurrent outcome identical to the serial one for the same seed.
//!
//! Orthogonally, `TuneBudget::pipeline_depth >= 2` pipelines each job's
//! *own* batches (plan batch k+1 while batch k measures — see
//! [`super::task_tuner`]); dispatcher admission permits are then held per
//! in-flight batch, not per tenant turn, so a pipelining tenant queues
//! one FIFO ticket per submitted batch and releases each slot the moment
//! that batch's measurement returns. Depth 1 (the default) keeps every
//! driver shape bit-identical to the pre-pipelining code.

use super::strategy::Strategy;
use super::task_tuner::{
    tune_task_tenant, tune_task_with, TaskTuneResult, TenantContext, TuneBudget,
};
use crate::baselines::autotvm::AutoTvmParams;
use crate::baselines::chameleon::ChameleonParams;
use crate::baselines::{AutoTvm, Chameleon, RandomSearch};
use crate::eval;
use crate::eval::{BudgetLedger, Dispatcher, LedgerStats};
use crate::marl::strategy::{Arco, ArcoParams};
use crate::space::ConfigSpace;
use crate::util::pool::parallel_map;
use crate::workload::{Conv2dTask, ModelSpec};

/// Frameworks under comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Framework {
    AutoTvm,
    Chameleon,
    Arco,
    /// Ablations / sanity baselines.
    Random,
    /// ARCO with Confidence Sampling disabled (Fig. 4 "before").
    ArcoNoCs,
    /// ARCO with hardware knobs frozen (isolates the co-design gain).
    ArcoSwOnly,
}

impl Framework {
    pub fn name(self) -> &'static str {
        match self {
            Framework::AutoTvm => "autotvm",
            Framework::Chameleon => "chameleon",
            Framework::Arco => "arco",
            Framework::Random => "random",
            Framework::ArcoNoCs => "arco-nocs",
            Framework::ArcoSwOnly => "arco-swonly",
        }
    }

    pub fn from_name(s: &str) -> Option<Framework> {
        Some(match s {
            "autotvm" => Framework::AutoTvm,
            "chameleon" => Framework::Chameleon,
            "arco" => Framework::Arco,
            "random" => Framework::Random,
            "arco-nocs" => Framework::ArcoNoCs,
            "arco-swonly" => Framework::ArcoSwOnly,
            _ => return None,
        })
    }

    /// The paper's three (Figs. 5-7, Table 6).
    pub fn paper_set() -> Vec<Framework> {
        vec![Framework::AutoTvm, Framework::Chameleon, Framework::Arco]
    }

    /// Does this framework explore hardware knobs?
    pub fn tunes_hardware(self) -> bool {
        matches!(self, Framework::Arco | Framework::ArcoNoCs)
    }

    /// Instantiate a strategy for one task space.
    ///
    /// A software-only framework must never see tunable hardware knobs,
    /// whatever space the caller hands it: the hardware-frozen constraint
    /// is enforced here instead of trusting every call site to consult
    /// [`tunes_hardware`](Self::tunes_hardware) first. (Knob *indices* are
    /// identical between the frozen and full variants of a space, so
    /// points planned in the frozen clone remain valid for the caller's.)
    pub fn build(self, mut space: ConfigSpace, quick: bool, seed: u64) -> Box<dyn Strategy> {
        space.hardware_tunable = space.hardware_tunable && self.tunes_hardware();
        match self {
            Framework::AutoTvm => {
                let p = if quick { AutoTvmParams::quick() } else { AutoTvmParams::default() };
                Box::new(AutoTvm::new(space, p, seed))
            }
            Framework::Chameleon => {
                let p = if quick { ChameleonParams::quick() } else { ChameleonParams::default() };
                Box::new(Chameleon::new(space, p, seed))
            }
            Framework::Arco | Framework::ArcoSwOnly => {
                let p = if quick { ArcoParams::quick() } else { ArcoParams::default() };
                Box::new(Arco::new(space, p, seed))
            }
            Framework::ArcoNoCs => {
                let mut p = if quick { ArcoParams::quick() } else { ArcoParams::default() };
                p.use_cs = false;
                Box::new(Arco::new(space, p, seed))
            }
            Framework::Random => Box::new(RandomSearch::new(space, seed)),
        }
    }
}

/// Per-task outcome inside a model run.
#[derive(Debug, Clone)]
pub struct TaskOutcome {
    pub task_id: String,
    pub weight: usize,
    pub result: TaskTuneResult,
}

/// One (framework, model) outcome.
#[derive(Debug, Clone)]
pub struct ModelOutcome {
    pub framework: Framework,
    pub model: String,
    pub tasks: Vec<TaskOutcome>,
    /// End-to-end mean inference time (s): Σ weight × best task runtime.
    pub inference_secs: f64,
    /// Total compilation time across tasks (s): search wall-clock plus the
    /// modeled hardware-measurement time (overhead + repeats x runtime per
    /// config) — the quantity the paper's Fig. 6 compares.
    pub compile_secs: f64,
    /// Search-only wall-clock (planner/learner compute, excl. measurements).
    pub search_secs: f64,
    /// Total hardware measurements spent (debited).
    pub measurements: usize,
    /// Of `measurements`, points freshly simulated for this framework.
    pub fresh: usize,
    /// Of `measurements`, points served from shared state another tenant
    /// (or an earlier batch) already paid for.
    pub cache_served: usize,
    /// Planned candidates resolved at *screening* fidelity (scored by the
    /// calibrated analytical model, never simulated) under
    /// `--fidelity screen:<keep>`. Zero in exact mode; not part of
    /// `measurements`.
    pub screened: usize,
}

impl ModelOutcome {
    /// Throughput in inferences/second.
    pub fn throughput(&self) -> f64 {
        if self.inference_secs > 0.0 {
            1.0 / self.inference_secs
        } else {
            0.0
        }
    }
}

/// Full comparison report (all frameworks × one model).
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub model: String,
    pub outcomes: Vec<ModelOutcome>,
    /// Equal-budget accounting, present when the run used a shared
    /// [`BudgetLedger`] ([`DriverOptions::shared_budget`]).
    pub ledger: Option<LedgerStats>,
}

impl CompareReport {
    pub fn outcome(&self, f: Framework) -> Option<&ModelOutcome> {
        self.outcomes.iter().find(|o| o.framework == f)
    }

    /// Fig. 6's optimization-time metric: modeled time for `f` to reach
    /// AutoTVM's final per-task quality (time-to-parity), plus its own
    /// search compute. The paper benchmarks at "the same AutoTVM
    /// compilation duration"; time-to-parity is the inverse view of that
    /// protocol and is robust to frameworks with different space sizes.
    /// A missing or nothing-valid baseline task yields a non-positive
    /// target, which `modeled_secs_to_quality` treats as never reached
    /// (full modeled time) rather than "parity at the first trace entry".
    pub fn compile_secs_to_parity(&self, f: Framework) -> Option<f64> {
        let base = self.outcome(Framework::AutoTvm)?;
        let ours = self.outcome(f)?;
        let mut total = ours.search_secs;
        for t in &ours.tasks {
            let target = base
                .tasks
                .iter()
                .find(|b| b.task_id == t.task_id)
                .map(|b| b.result.best.gflops)
                .unwrap_or(0.0);
            total += t.result.modeled_secs_to_quality(target);
        }
        Some(total)
    }

    /// Throughput of `f` normalized to AutoTVM (Fig. 5's y-axis).
    pub fn throughput_vs_autotvm(&self, f: Framework) -> Option<f64> {
        let base = self.outcome(Framework::AutoTvm)?.throughput();
        let ours = self.outcome(f)?.throughput();
        if base > 0.0 {
            Some(ours / base)
        } else {
            None
        }
    }
}

/// How the comparison driver schedules its (framework, task) jobs.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DriverOptions {
    /// Run every job concurrently over the shared engine, interleaved by a
    /// FIFO dispatcher sized to the fleet's batch capacity. Off: the
    /// classic serial framework-major order.
    pub concurrent: bool,
    /// Enforce the equal-budget protocol with a shared [`BudgetLedger`]:
    /// every (framework, task) tenant is debited per planned point —
    /// cache-served or fresh — against the same per-task allowance, and
    /// the report carries the ledger stats.
    pub shared_budget: bool,
}

impl DriverOptions {
    fn multi_tenant(self) -> bool {
        self.concurrent || self.shared_budget
    }
}

/// Shared multi-tenant infrastructure for one comparison run: every
/// (framework, task) job charges the same ledger and queues on the same
/// dispatcher.
pub struct SharedRun {
    ledger: Option<BudgetLedger>,
    dispatcher: Dispatcher,
}

impl SharedRun {
    /// Infrastructure for one run: a ledger granting each (framework,
    /// task) tenant `budget.total_measurements` points (when
    /// `shared_budget`), and a dispatcher sized to the engine's current
    /// concurrent batch capacity (re-read as the run progresses).
    pub fn new(engine: &eval::Engine, budget: &TuneBudget, shared_budget: bool) -> SharedRun {
        SharedRun {
            ledger: shared_budget.then(|| BudgetLedger::new(budget.total_measurements)),
            dispatcher: Dispatcher::new(engine.concurrent_batch_capacity()),
        }
    }

    pub fn ledger(&self) -> Option<&BudgetLedger> {
        self.ledger.as_ref()
    }

    pub fn ledger_stats(&self) -> Option<LedgerStats> {
        self.ledger.as_ref().map(|l| l.stats())
    }

    pub fn dispatcher(&self) -> &Dispatcher {
        &self.dispatcher
    }
}

/// One (framework, task) tuning job — the unit both drivers schedule.
/// `tenant_label` is the ledger identity (the framework name, uniquified
/// by the caller when a framework appears twice in one comparison).
/// `Err` is a lost measurement fleet; the drivers abort the comparison.
#[allow(clippy::too_many_arguments)]
fn run_job(
    engine: &eval::Engine,
    framework: Framework,
    tenant_label: &str,
    model_name: &str,
    task: &Conv2dTask,
    weight: usize,
    task_index: usize,
    task_count: usize,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
    shared: Option<&SharedRun>,
) -> anyhow::Result<TaskOutcome> {
    let space = ConfigSpace::for_task(task, framework.tunes_hardware());
    let mut strategy = framework.build(space.clone(), quick, seed ^ (task_index as u64) << 32);
    let task_id = task.short_id();
    let result = match shared {
        Some(s) => {
            let tenant = TenantContext {
                ledger: s.ledger.as_ref(),
                dispatcher: &s.dispatcher,
                framework: tenant_label,
                task_id: &task_id,
                observer: None,
            };
            tune_task_tenant(engine, &space, strategy.as_mut(), budget, Some(&tenant))?
        }
        None => tune_task_with(engine, &space, strategy.as_mut(), budget)?,
    };
    crate::log_info!(
        "compare",
        "{} {} task {}/{} {}: best {:.3e}s over {} measurements ({} fresh, {} shared) ({})",
        framework.name(),
        model_name,
        task_index + 1,
        task_count,
        task_id,
        result.best.seconds,
        result.measurements,
        result.fresh,
        result.cache_served,
        strategy.diag()
    );
    Ok(TaskOutcome { task_id, weight, result })
}

/// Roll task outcomes up into one (framework, model) aggregate.
fn aggregate(framework: Framework, model: &ModelSpec, tasks: Vec<TaskOutcome>) -> ModelOutcome {
    let mut inference_secs = 0.0f64;
    let mut compile_secs = 0.0f64;
    let mut search_secs = 0.0f64;
    let mut measurements = 0usize;
    let mut fresh = 0usize;
    let mut cache_served = 0usize;
    let mut screened = 0usize;
    for t in &tasks {
        inference_secs += t.weight as f64 * t.result.best.seconds;
        compile_secs += t.result.wall_secs + t.result.modeled_hw_secs;
        search_secs += t.result.wall_secs;
        measurements += t.result.measurements;
        fresh += t.result.fresh;
        cache_served += t.result.cache_served;
        screened += t.result.screened;
    }
    ModelOutcome {
        framework,
        model: model.name.to_string(),
        tasks,
        inference_secs,
        compile_secs,
        search_secs,
        measurements,
        fresh,
        cache_served,
        screened,
    }
}

/// Tune one model end-to-end with one framework, using a private default
/// measurement engine. Prefer [`tune_model_with`] with a shared engine when
/// running several frameworks or models: tasks repeated across frameworks
/// are then simulated once and served from the cache afterwards.
///
/// `Err` on every model-level driver means the measurement infrastructure
/// was lost (a remote fleet with no reachable shard); local backends never
/// fail.
pub fn tune_model(
    framework: Framework,
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
) -> anyhow::Result<ModelOutcome> {
    let engine = eval::Engine::vta_sim(budget.workers);
    tune_model_with(&engine, framework, model, budget, quick, seed)
}

/// Tune one model end-to-end with one framework through a shared engine,
/// tasks in series.
pub fn tune_model_with(
    engine: &eval::Engine,
    framework: Framework,
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
) -> anyhow::Result<ModelOutcome> {
    let uniq = model.unique_tasks();
    let tasks: Vec<TaskOutcome> = uniq
        .iter()
        .enumerate()
        .map(|(i, (task, weight))| {
            run_job(
                engine,
                framework,
                framework.name(),
                model.name,
                task,
                *weight,
                i,
                uniq.len(),
                budget,
                quick,
                seed,
                None,
            )
        })
        .collect::<anyhow::Result<_>>()?;
    Ok(aggregate(framework, model, tasks))
}

/// [`tune_model_with`] with every task tuned as a concurrent tenant of
/// `shared`: each (framework, task) job runs on a `util::pool` thread, the
/// shared dispatcher interleaves their measurement batches, and (when the
/// run carries a ledger) each tenant is debited per planned point. The
/// measurement backends are deterministic, so the outcome — best points,
/// measurement counts, traces — is identical to the serial driver's for
/// the same seed; only wall-clock scheduling differs.
pub fn tune_model_concurrent(
    engine: &eval::Engine,
    framework: Framework,
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
    shared: &SharedRun,
) -> anyhow::Result<ModelOutcome> {
    let uniq = model.unique_tasks();
    let indices: Vec<usize> = (0..uniq.len()).collect();
    let tasks: Vec<TaskOutcome> = parallel_map(&indices, indices.len().max(1), |_, &i| {
        let (task, weight) = &uniq[i];
        run_job(
            engine,
            framework,
            framework.name(),
            model.name,
            task,
            *weight,
            i,
            uniq.len(),
            budget,
            quick,
            seed,
            Some(shared),
        )
    })
    .into_iter()
    .collect::<anyhow::Result<_>>()?;
    Ok(aggregate(framework, model, tasks))
}

/// Compare a set of frameworks on one model. All frameworks share one
/// measurement engine, so a configuration measured by one framework is a
/// cache hit for every later framework that plans it.
pub fn compare_frameworks(
    frameworks: &[Framework],
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
) -> anyhow::Result<CompareReport> {
    let engine = eval::Engine::vta_sim(budget.workers);
    compare_frameworks_with(&engine, frameworks, model, budget, quick, seed)
}

/// [`compare_frameworks`] over a caller-provided engine (shared cache /
/// journal across models and processes), serial driver.
pub fn compare_frameworks_with(
    engine: &eval::Engine,
    frameworks: &[Framework],
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
) -> anyhow::Result<CompareReport> {
    let opts = DriverOptions::default();
    compare_frameworks_opts(engine, frameworks, model, budget, quick, seed, opts)
}

/// The full driver. With [`DriverOptions::concurrent`], every (framework,
/// task) job becomes a tenant competing for the shared engine/fleet —
/// jobs spawn on `util::pool`, the dispatcher interleaves their batches
/// FIFO, and the task seeds match the serial driver's so a deterministic
/// backend reproduces its results exactly. With
/// [`DriverOptions::shared_budget`], a [`BudgetLedger`] additionally
/// enforces the equal-budget protocol and its stats land on the report.
pub fn compare_frameworks_opts(
    engine: &eval::Engine,
    frameworks: &[Framework],
    model: &ModelSpec,
    budget: TuneBudget,
    quick: bool,
    seed: u64,
    opts: DriverOptions,
) -> anyhow::Result<CompareReport> {
    let uniq = model.unique_tasks();
    let shared = SharedRun::new(engine, &budget, opts.shared_budget);
    let shared_ref = opts.multi_tenant().then_some(&shared);

    // Ledger identities: the framework name, uniquified when the same
    // framework is listed twice (two "random" entries must not drain one
    // shared allowance).
    let labels: Vec<String> = frameworks
        .iter()
        .enumerate()
        .map(|(i, f)| {
            let dups_before = frameworks[..i].iter().filter(|g| **g == *f).count();
            if dups_before == 0 {
                f.name().to_string()
            } else {
                format!("{}#{}", f.name(), dups_before + 1)
            }
        })
        .collect();

    // Flat (framework, task) job list, framework-major so the serial path
    // reproduces the original driver's order exactly.
    let jobs: Vec<(usize, usize)> = (0..frameworks.len())
        .flat_map(|f| (0..uniq.len()).map(move |t| (f, t)))
        .collect();
    let pool_workers = if opts.concurrent { jobs.len().max(1) } else { 1 };
    let flat: Vec<TaskOutcome> = parallel_map(&jobs, pool_workers, |_, &(f, t)| {
        let (task, weight) = &uniq[t];
        run_job(
            engine,
            frameworks[f],
            &labels[f],
            model.name,
            task,
            *weight,
            t,
            uniq.len(),
            budget,
            quick,
            seed,
            shared_ref,
        )
    })
    .into_iter()
    .collect::<anyhow::Result<_>>()?;

    // Regroup framework-major (parallel_map preserves input order).
    let mut outcomes = Vec::with_capacity(frameworks.len());
    let mut flat = flat.into_iter();
    for &f in frameworks {
        let tasks: Vec<TaskOutcome> = flat.by_ref().take(uniq.len()).collect();
        outcomes.push(aggregate(f, model, tasks));
    }
    crate::log_info!("compare", "{}: eval {}", model.name, engine.summary());
    if opts.concurrent {
        let d = shared.dispatcher.stats();
        crate::log_info!(
            "compare",
            "{}: dispatcher slots={} dispatched={} waited={} peak_queue={} pipeline_depth={}",
            model.name,
            d.slots,
            d.dispatched,
            d.waited,
            d.peak_queue,
            budget.pipeline_depth.max(1)
        );
    }
    if let Some(stats) = shared.ledger_stats() {
        crate::log_info!("compare", "{}: ledger {}", model.name, stats.summary());
    }
    Ok(CompareReport {
        model: model.name.to_string(),
        outcomes,
        ledger: shared.ledger_stats(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::{AnalyticalBackend, Engine};
    use crate::workload::model_by_name;

    fn tiny_budget() -> TuneBudget {
        TuneBudget { total_measurements: 48, batch: 16, workers: 2, ..Default::default() }
    }

    #[test]
    fn framework_names_roundtrip() {
        for f in [
            Framework::AutoTvm,
            Framework::Chameleon,
            Framework::Arco,
            Framework::Random,
            Framework::ArcoNoCs,
            Framework::ArcoSwOnly,
        ] {
            assert_eq!(Framework::from_name(f.name()), Some(f));
        }
        assert_eq!(Framework::from_name("nope"), None);
    }

    #[test]
    fn hardware_tuning_partition() {
        assert!(Framework::Arco.tunes_hardware());
        assert!(!Framework::AutoTvm.tunes_hardware());
        assert!(!Framework::Chameleon.tunes_hardware());
        assert!(!Framework::ArcoSwOnly.tunes_hardware());
    }

    #[test]
    fn arco_swonly_never_varies_a_hardware_knob() {
        // Regression: build() must enforce the frozen-hardware constraint
        // even when handed a fully-tunable space, and no planning path —
        // exploration, CS selection, CS *synthesis*, random fallback —
        // may emit a point with non-default hardware.
        let task = crate::workload::Conv2dTask::new(1, 64, 28, 28, 64, 3, 3, 1, 1);
        let tunable = ConfigSpace::for_task(&task, true);
        let engine = Engine::with_backend(Box::new(AnalyticalBackend), 2, true);
        let mut strategy = Framework::ArcoSwOnly.build(tunable.clone(), true, 23);
        for _round in 0..4 {
            let plan = strategy.plan(16);
            for p in &plan {
                let (hw, _) = tunable.decode(p);
                assert_eq!(
                    (hw.batch, hw.block_in, hw.block_out),
                    (1, 16, 16),
                    "arco-swonly planned non-default hardware: {}",
                    tunable.render(p)
                );
            }
            strategy.observe(&engine.measure_paired(&tunable, plan).pairs);
        }
    }

    #[test]
    fn tune_model_aggregates_weighted_inference_time() {
        // AlexNet is the smallest zoo model (5 tasks, weight 1 each).
        let model = model_by_name("alexnet").unwrap();
        let out = tune_model(Framework::Random, &model, tiny_budget(), true, 3).unwrap();
        assert_eq!(out.tasks.len(), model.unique_tasks().len());
        let manual: f64 = out
            .tasks
            .iter()
            .map(|t| t.weight as f64 * t.result.best.seconds)
            .sum();
        assert!((out.inference_secs - manual).abs() < 1e-12);
        assert!(out.inference_secs.is_finite() && out.inference_secs > 0.0);
        // Budget is an upper bound: tiny layers (e.g. 13x13 planes with only
        // two tile candidates per dim) have spaces smaller than the budget
        // and exhaust early.
        for t in &out.tasks {
            assert!(t.result.measurements <= 48);
            assert!(t.result.measurements > 0);
            assert_eq!(t.result.fresh + t.result.cache_served, t.result.measurements);
        }
        assert!(out.measurements <= 48 * model.unique_tasks().len());
        assert_eq!(out.fresh + out.cache_served, out.measurements);
    }

    #[test]
    fn compare_report_normalizes_to_autotvm() {
        let model = model_by_name("alexnet").unwrap();
        let report = compare_frameworks(
            &[Framework::AutoTvm, Framework::Random],
            &model,
            tiny_budget(),
            true,
            5,
        )
        .unwrap();
        let rel = report.throughput_vs_autotvm(Framework::AutoTvm).unwrap();
        assert!((rel - 1.0).abs() < 1e-12);
        assert!(report.throughput_vs_autotvm(Framework::Random).unwrap() > 0.0);
        // The serial driver carries no ledger.
        assert!(report.ledger.is_none());
    }

    #[test]
    fn shared_budget_driver_debits_and_reports() {
        let model = model_by_name("alexnet").unwrap();
        let budget =
            TuneBudget { total_measurements: 8, batch: 4, workers: 2, ..Default::default() };
        let engine = Engine::with_backend(Box::new(AnalyticalBackend), 2, true);
        let report = compare_frameworks_opts(
            &engine,
            &[Framework::Random, Framework::AutoTvm],
            &model,
            budget,
            true,
            5,
            DriverOptions { concurrent: true, shared_budget: true },
        )
        .unwrap();
        let ledger = report.ledger.as_ref().expect("shared-budget run must carry ledger stats");
        assert_eq!(ledger.per_task_points, 8);
        // Every tenant's settled points match its debits, and nothing
        // breached the per-task allowance.
        assert!(!ledger.tenants.is_empty());
        for t in &ledger.tenants {
            assert!(t.account.charged <= 8, "{}/{} over budget", t.framework, t.task);
            assert_eq!(t.account.settled(), t.account.charged);
        }
        // Outcome-side accounting agrees with the ledger.
        for o in &report.outcomes {
            let charged: usize = ledger
                .tenants
                .iter()
                .filter(|t| t.framework == o.framework.name())
                .map(|t| t.account.charged)
                .sum();
            assert_eq!(charged, o.measurements);
        }
    }

    #[test]
    fn pipelined_shared_budget_driver_matches_serial_and_conserves_ledger() {
        // Pipelined speed mode under the multi-tenant driver: random
        // search ignores observations, so its plans are identical at any
        // depth — the depth-2 concurrent run must reproduce the serial
        // depth-1 driver's numbers while the ledger stays conserved.
        let model = model_by_name("alexnet").unwrap();
        let serial_budget =
            TuneBudget { total_measurements: 12, batch: 4, workers: 2, ..Default::default() };
        let piped_budget = TuneBudget { pipeline_depth: 2, ..serial_budget };

        let serial_engine = Engine::with_backend(Box::new(AnalyticalBackend), 2, true);
        let serial = compare_frameworks_with(
            &serial_engine,
            &[Framework::Random],
            &model,
            serial_budget,
            true,
            11,
        )
        .unwrap();

        let piped_engine = Engine::with_backend(Box::new(AnalyticalBackend), 2, true);
        let piped = compare_frameworks_opts(
            &piped_engine,
            &[Framework::Random],
            &model,
            piped_budget,
            true,
            11,
            DriverOptions { concurrent: true, shared_budget: true },
        )
        .unwrap();

        for (s, p) in serial.outcomes.iter().zip(&piped.outcomes) {
            assert_eq!(s.inference_secs, p.inference_secs, "pipelining changed the numbers");
            assert_eq!(s.measurements, p.measurements);
            for (st, pt) in s.tasks.iter().zip(&p.tasks) {
                assert_eq!(st.result.best_point, pt.result.best_point, "task {}", st.task_id);
                assert_eq!(st.result.measurements, pt.result.measurements);
            }
        }
        let ledger = piped.ledger.as_ref().expect("shared-budget run must carry ledger stats");
        for t in &ledger.tenants {
            assert!(t.account.charged <= 12, "{}/{} over budget", t.framework, t.task);
            assert_eq!(t.account.settled(), t.account.charged, "in-flight charge never settled");
        }
    }

    #[test]
    fn duplicate_frameworks_get_separate_ledger_accounts() {
        let model = model_by_name("alexnet").unwrap();
        let budget =
            TuneBudget { total_measurements: 6, batch: 3, workers: 2, ..Default::default() };
        let engine = Engine::with_backend(Box::new(AnalyticalBackend), 2, true);
        let report = compare_frameworks_opts(
            &engine,
            &[Framework::Random, Framework::Random],
            &model,
            budget,
            true,
            7,
            DriverOptions { concurrent: false, shared_budget: true },
        )
        .unwrap();
        // Both entries must spend their own allowance, not drain one.
        assert_eq!(report.outcomes[0].measurements, report.outcomes[1].measurements);
        let ledger = report.ledger.unwrap();
        assert!(ledger.tenants.iter().any(|t| t.framework == "random"));
        assert!(ledger.tenants.iter().any(|t| t.framework == "random#2"));
        // The second pass replans the identical points: all cache-served.
        let second: usize = ledger
            .tenants
            .iter()
            .filter(|t| t.framework == "random#2")
            .map(|t| t.account.cache_served)
            .sum();
        let second_charged: usize = ledger
            .tenants
            .iter()
            .filter(|t| t.framework == "random#2")
            .map(|t| t.account.charged)
            .sum();
        assert_eq!(second, second_charged, "identical replans must be fully cache-served");
        assert!(second > 0);
    }
}
