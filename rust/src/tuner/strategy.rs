//! The strategy interface every framework implements.

use crate::eval::MeasureResult;
use crate::space::PointConfig;

/// A search strategy: plans measurement batches, learns from results.
///
/// The orchestrator ([`super::tune_task`]) owns the measurement budget and
/// the [`crate::eval::Engine`] that batches, caches and parallelizes the
/// hardware measurements; strategies only decide *what* to measure next.
/// This is the same division AutoTVM/CHAMELEON/ARCO share in the paper
/// (§2.3's argmax over f[τ(Θ)] with different explorers/samplers plugged
/// in).
///
/// # Pipelined lifecycle
///
/// The classic (paper-faithful) loop is strictly serial: `plan` → measure
/// → `observe`, one batch at a time, so every plan sees the results of
/// every earlier plan. With `--pipeline-depth N` (N ≥ 2) the orchestrator
/// instead *overlaps* strategy compute with in-flight hardware
/// measurement: while batch *k* is still being measured it already calls
/// `plan` for batch *k+1* from the strategy's **current** posterior, and
/// delivers `observe` calls as batches drain — always in submission
/// order, but up to [`max_pipeline_depth`](Self::max_pipeline_depth)
/// batches late. Two contract consequences:
///
/// - `plan` may be called while earlier plans have no results yet. A
///   strategy must track its own outstanding proposals so it never
///   re-proposes an in-flight point (every in-tree strategy marks points
///   in its `seen` set at plan time, which satisfies this for free).
/// - `observe` may deliver results for points planned several batches
///   ago. Model refits simply see the data a little late — the staleness
///   the speed mode trades for wall-clock.
///
/// The orchestrator clamps the configured depth to
/// [`max_pipeline_depth`](Self::max_pipeline_depth), so a strategy that
/// cannot tolerate stale observations keeps its serial semantics even
/// when the run asks for the speed mode.
pub trait Strategy {
    /// Framework name for reports.
    fn name(&self) -> &'static str;

    /// Propose up to `batch` *distinct, unmeasured, not-in-flight*
    /// configurations. Returning fewer (or none) ends the tuning run
    /// early (in a pipelined run the orchestrator still drains and
    /// delivers every in-flight batch before stopping).
    fn plan(&mut self, batch: usize) -> Vec<PointConfig>;

    /// Digest a batch of hardware measurements. Delivered in submission
    /// order; under a pipelined orchestrator the points may have been
    /// planned up to `max_pipeline_depth - 1` batches before the most
    /// recent `plan` call.
    fn observe(&mut self, results: &[(PointConfig, MeasureResult)]);

    /// Digest *low-fidelity* observations: points the multi-fidelity
    /// screening stage (`--fidelity screen:<keep>`) scored with the
    /// calibrated analytical model and filtered out before the simulator.
    /// The estimates rank candidates well but are not cycle-accurate, so
    /// they arrive through this separate channel — a posterior can weight
    /// (or ignore) them without ever confusing them for measurements.
    ///
    /// The default drops them: a strategy that only trusts the oracle
    /// keeps exactly its exact-mode behaviour, merely observing fewer
    /// measured points per planned batch. Implementations must still treat
    /// these points as *consumed* (they were planned, so in-tree
    /// strategies' plan-time `seen` marking already covers this).
    fn observe_low_fidelity(&mut self, _results: &[(PointConfig, MeasureResult)]) {}

    /// The deepest measurement pipeline this strategy tolerates: how many
    /// batches may be in flight (planned but unobserved) at once. `1`
    /// means strictly serial — every `plan` sees every earlier result —
    /// and is the conservative default for implementations that have not
    /// audited their plan/observe coupling. Strategies that track
    /// in-flight proposals themselves (all in-tree ones) return
    /// `usize::MAX` and let the run's `--pipeline-depth` bound the
    /// overlap.
    fn max_pipeline_depth(&self) -> usize {
        1
    }

    /// Optional: strategy-specific diagnostics line for logs.
    fn diag(&self) -> String {
        String::new()
    }
}
