//! The strategy interface every framework implements.

use crate::eval::MeasureResult;
use crate::space::PointConfig;

/// A search strategy: plans measurement batches, learns from results.
///
/// The orchestrator ([`super::tune_task`]) owns the measurement budget and
/// the [`crate::eval::Engine`] that batches, caches and parallelizes the
/// hardware measurements; strategies only decide *what* to measure next.
/// This is the same division AutoTVM/CHAMELEON/ARCO share in the paper
/// (§2.3's argmax over f[τ(Θ)] with different explorers/samplers plugged
/// in).
pub trait Strategy {
    /// Framework name for reports.
    fn name(&self) -> &'static str;

    /// Propose up to `batch` *distinct, unmeasured* configurations.
    /// Returning fewer (or none) ends the tuning run early.
    fn plan(&mut self, batch: usize) -> Vec<PointConfig>;

    /// Digest a batch of hardware measurements.
    fn observe(&mut self, results: &[(PointConfig, MeasureResult)]);

    /// Optional: strategy-specific diagnostics line for logs.
    fn diag(&self) -> String {
        String::new()
    }
}
