//! Per-task tuning loop: budgeted plan → batched engine measure → observe.
//!
//! Two execution shapes share one implementation:
//!
//! - **Serial** (`pipeline_depth == 1`, the paper-faithful default): one
//!   batch at a time — every plan sees every earlier result, reproducing
//!   the classic lockstep loop bit for bit.
//! - **Pipelined** (`pipeline_depth >= 2`, the speed mode): batch *k* is
//!   submitted to the engine asynchronously
//!   ([`eval::Engine::submit_batch`]) and, while it is in flight, the
//!   strategy already plans batch *k+1* from its current posterior.
//!   Completions drain strictly in submission order, so trace ordinals
//!   stay in order; the ledger is charged *before* each submission, so an
//!   in-flight pipeline can never overshoot a budget; and both a strategy
//!   early-stop and a lost measurement fleet drain every in-flight batch
//!   before the loop returns. On a remote fleet this hides the search
//!   compute behind measurement RTT — wall-clock approaches
//!   `max(search, measure)` instead of their sum.

use super::strategy::Strategy;
use crate::eval::{self, BudgetLedger, Dispatcher, MeasureResult};
use crate::space::{ConfigSpace, PointConfig};
use crate::util::rng::Pcg32;
use crate::util::timer::{PhaseTimer, Stopwatch};
use std::collections::VecDeque;

/// Modeled testbed seconds one analytical screening evaluation costs —
/// the low-fidelity tier's price on the [`BudgetLedger`]
/// ([`BudgetLedger::charge_screen`]). A few hundred nanoseconds of real
/// compute, charged as a microsecond so equal-cost comparisons stay
/// honest without letting screening distort Fig. 6 time axes.
pub const SCREEN_COST_SECS: f64 = 1e-6;

/// Exploration fraction used when `screen:<keep>` does not spell one out.
pub const DEFAULT_EXPLORE_FRAC: f64 = 0.1;

/// Evaluation fidelity of the tuning loop (`--fidelity`).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub enum Fidelity {
    /// Every planned candidate goes to the measurement engine — the
    /// paper-faithful default, bit-identical to the classic loop.
    #[default]
    Exact,
    /// Multi-fidelity screening: each admitted batch is first scored by
    /// the online-calibrated analytical model; only the top `keep`
    /// fraction — plus an ε-greedy `explore` slice drawn from the
    /// filtered-out tail, so the model cannot permanently lock out regions
    /// it misranks — goes to the simulator. The rest feed the strategy as
    /// low-fidelity observations ([`Strategy::observe_low_fidelity`]).
    Screen {
        /// Fraction of each admitted batch sent to the simulator (0, 1].
        keep: f64,
        /// Fraction of the kept count re-drawn uniformly from the rejected
        /// tail [0, 1].
        explore: f64,
    },
}

impl Fidelity {
    /// Parse a CLI/config fidelity string: `exact`, `screen:<keep>` or
    /// `screen:<keep>:<explore>` (fractions; keep in (0, 1], explore in
    /// [0, 1]).
    pub fn parse(s: &str) -> Option<Fidelity> {
        if s == "exact" {
            return Some(Fidelity::Exact);
        }
        let rest = s.strip_prefix("screen:")?;
        let mut parts = rest.splitn(2, ':');
        let keep: f64 = parts.next()?.trim().parse().ok()?;
        let explore: f64 = match parts.next() {
            Some(e) => e.trim().parse().ok()?,
            None => DEFAULT_EXPLORE_FRAC,
        };
        if !(keep > 0.0 && keep <= 1.0) || !(0.0..=1.0).contains(&explore) {
            return None;
        }
        Some(Fidelity::Screen { keep, explore })
    }

    /// Canonical rendering; `Fidelity::parse` round-trips it.
    pub fn describe(&self) -> String {
        match self {
            Fidelity::Exact => "exact".to_string(),
            Fidelity::Screen { keep, explore } => format!("screen:{keep}:{explore}"),
        }
    }

    pub fn is_screen(&self) -> bool {
        matches!(self, Fidelity::Screen { .. })
    }
}

/// Which tier produced a trace entry (the tag Fig. 6 plots filter on, so
/// convergence curves chart simulator-seconds, not screened points).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TraceFidelity {
    /// A real engine measurement (simulator or cache-served).
    #[default]
    Exact,
    /// A calibrated-analytical screening estimate; never measured.
    Screened,
}

/// Measurement budget (Table 4/5: Σb = 1000, b = 64).
#[derive(Debug, Clone, Copy)]
pub struct TuneBudget {
    /// Total hardware measurements allowed.
    pub total_measurements: usize,
    /// Measurements per iteration (planning batch).
    pub batch: usize,
    /// Worker threads for parallel simulation. Only consulted when
    /// [`tune_task`] builds its own default engine; an engine passed to
    /// [`tune_task_with`] brings its own worker pool.
    pub workers: usize,
    /// Area feasibility ceiling (mm²) for the *final* configuration:
    /// configurations above it are measured (they inform the cost model)
    /// but can never be selected as best — an over-budget accelerator is
    /// not implementable (Eq. 4's hard form).
    pub area_budget_mm2: f64,
    /// Planning iterations allowed (Table 4's iteration_opt=16). Strategies
    /// that plan fewer configs per iteration (ARCO's Confidence Sampling)
    /// therefore spend fewer total hardware measurements.
    pub max_iterations: usize,
    /// Modeled cost of one hardware measurement on a real testbed:
    /// fixed setup/transfer overhead (s)...
    pub measure_overhead_secs: f64,
    /// ...plus `repeats` timed runs of the configuration...
    pub measure_repeats: usize,
    /// ...and a timeout charge for invalid configurations (a build/run
    /// failure still wastes wall-clock on real hardware).
    pub invalid_timeout_secs: f64,
    /// Measurement batches the loop may have in flight at once
    /// (`--pipeline-depth`). `1` (default) is the paper-faithful serial
    /// loop: plan, measure, observe, repeat — reproduced bit-identically.
    /// `>= 2` is the speed mode: the strategy plans batch *k+1* while
    /// batch *k* is still on the hardware, trading posterior freshness
    /// (observations arrive up to `depth - 1` batches late) for
    /// wall-clock. Clamped to [`Strategy::max_pipeline_depth`]; values
    /// below 1 behave as 1.
    pub pipeline_depth: usize,
    /// Evaluation fidelity (`--fidelity`). [`Fidelity::Exact`] (default)
    /// sends every admitted candidate to the engine — bit-identical to
    /// the classic loop. [`Fidelity::Screen`] scores each admitted batch
    /// with the engine's online-calibrated analytical model first and
    /// only forwards the most promising fraction (plus an exploration
    /// slice) to the simulator.
    pub fidelity: Fidelity,
}

impl Default for TuneBudget {
    fn default() -> Self {
        TuneBudget {
            total_measurements: 1000,
            batch: 64,
            workers: crate::util::pool::default_workers(),
            area_budget_mm2: crate::vta::area::default_area_budget_mm2(),
            max_iterations: 16,
            measure_overhead_secs: 0.05,
            measure_repeats: 10,
            invalid_timeout_secs: 1.0,
            pipeline_depth: 1,
            fidelity: Fidelity::Exact,
        }
    }
}

/// One measured configuration in the tuning trace (Fig. 4 / Fig. 7 data).
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Measurement ordinal (1-based).
    pub ordinal: usize,
    /// Iteration the measurement belonged to.
    pub iteration: usize,
    /// Seconds of *this job's* clock when this was measured: wall-clock
    /// since tuning started minus time spent queued behind competing
    /// tenants at the dispatcher — the same queue-excluded clock as
    /// [`TaskTuneResult::wall_secs`], so concurrent-driver convergence
    /// curves (Fig. 6) line up with the serial driver's instead of
    /// shifting right by arrival-order-dependent scheduling wait.
    pub at_secs: f64,
    /// Achieved GFLOPS (0 for invalid configs).
    pub gflops: f64,
    /// Best GFLOPS so far (running max).
    pub best_gflops: f64,
    /// Whether the config was valid.
    pub valid: bool,
    /// Cumulative *modeled* hardware-measurement time (s) up to and
    /// including this measurement (see `TuneBudget::measure_overhead_secs`).
    pub modeled_cum_secs: f64,
    /// Which tier produced this entry: a real measurement
    /// ([`TraceFidelity::Exact`]) or a calibrated-analytical screening
    /// estimate ([`TraceFidelity::Screened`], only under
    /// `--fidelity screen:<keep>`). Fig. 6 style time-axis plots filter
    /// to `Exact` so curves chart simulator-seconds.
    pub fidelity: TraceFidelity,
}

/// Outcome of tuning one task.
#[derive(Debug, Clone)]
pub struct TaskTuneResult {
    pub best_point: Option<PointConfig>,
    pub best: MeasureResult,
    pub measurements: usize,
    /// Of `measurements`, points whose simulation actually ran for this
    /// job (see [`crate::eval::Origin`]).
    pub fresh: usize,
    /// Of `measurements`, points served from shared state (cache, dedup,
    /// coalescing, fleet shard caches) — same debit, no simulator time.
    pub cache_served: usize,
    pub invalid: usize,
    /// Wall-clock of this job excluding time spent queued behind competing
    /// tenants at the dispatcher (scheduling wait is not search compute;
    /// without the exclusion a concurrent run would report inflated,
    /// arrival-order-dependent search/compile seconds).
    pub wall_secs: f64,
    /// Modeled wall-clock a real testbed would spend on the hardware
    /// measurements (overhead + repeats x runtime; timeout for invalid) —
    /// the dominant term of "compilation time" in the paper's Fig. 6.
    pub modeled_hw_secs: f64,
    /// Candidates the screening stage scored analytically and filtered out
    /// before the simulator (0 under `--fidelity exact`). Screened points
    /// are *not* part of `measurements`.
    pub screened: usize,
    /// Exploration-slice points (screen-rejected, measured anyway) that
    /// improved the running best — each one is a point the analytical
    /// filter would have wrongly discarded. A climbing rate signals the
    /// screening model is misranking this task (see docs/OPERATIONS.md).
    pub explore_hits: usize,
    pub trace: Vec<TraceEntry>,
    pub timer: PhaseTimer,
}

impl TaskTuneResult {
    /// Best measured task runtime in seconds (inf if nothing valid).
    pub fn best_seconds(&self) -> f64 {
        self.best.seconds
    }

    /// Modeled time (s) until the running best first reached
    /// `target_gflops` — the time-to-quality metric behind Fig. 6.
    /// Returns the full modeled time if the target was never reached.
    ///
    /// A non-positive (or NaN) target is degenerate — it usually means the
    /// baseline found nothing valid — and is treated as *never reached*:
    /// otherwise the very first trace entry, even an invalid config with
    /// `best_gflops == 0`, would "reach parity" instantly and make the
    /// time-to-parity comparison meaningless.
    pub fn modeled_secs_to_quality(&self, target_gflops: f64) -> f64 {
        if target_gflops <= 0.0 || target_gflops.is_nan() {
            return self.modeled_hw_secs;
        }
        for e in &self.trace {
            if e.best_gflops >= target_gflops {
                return e.modeled_cum_secs;
            }
        }
        self.modeled_hw_secs
    }
}

/// Tune one task with a strategy under a budget, using a private default
/// measurement engine (cycle simulator backend, in-memory cache,
/// `budget.workers` threads). Prefer [`tune_task_with`] and a shared
/// [`eval::Engine`] when tuning several tasks or frameworks: a shared
/// engine pays for each unique configuration at most once across all of
/// them.
///
/// `Err` means the measurement infrastructure was lost mid-run (a remote
/// fleet with no reachable shard — [`crate::eval::FleetLostError`]); local
/// backends never fail.
pub fn tune_task(
    space: &ConfigSpace,
    strategy: &mut dyn Strategy,
    budget: TuneBudget,
) -> anyhow::Result<TaskTuneResult> {
    let engine = eval::Engine::vta_sim(budget.workers);
    tune_task_with(&engine, space, strategy, budget)
}

/// Live hooks into a running [`tune_task_tenant`] loop, for callers that
/// supervise jobs from outside the loop thread (the `arco serve-tune`
/// daemon). Both methods are called from the tuning thread itself:
/// `on_trace` once per trace entry the moment it is appended (in ordinal
/// order), and `cancelled` once per refill turn. A `true` from `cancelled`
/// ends the run through the normal early-stop path — in-flight batches
/// drain, completed ones settle on the ledger, and the partial
/// [`TaskTuneResult`] is returned intact.
pub trait TuneObserver {
    /// A trace entry was just appended (entries arrive in ordinal order).
    fn on_trace(&self, _entry: &TraceEntry) {}
    /// Polled between batches; `true` requests a cooperative early stop.
    fn cancelled(&self) -> bool {
        false
    }
}

/// Multi-tenant identity of one tuning job: who it is (for ledger
/// accounting) and which shared scheduling infrastructure its measurement
/// batches go through. Built by the concurrent comparison driver
/// ([`crate::tuner::compare`]); standalone runs pass `None` and keep the
/// classic single-tenant behaviour.
pub struct TenantContext<'a> {
    /// Equal-budget ledger charged before every batch (None: the
    /// dispatcher still interleaves, but only the local budget applies).
    pub ledger: Option<&'a BudgetLedger>,
    /// FIFO admission of measurement batches across competing jobs.
    pub dispatcher: &'a Dispatcher,
    /// Ledger identity, first key.
    pub framework: &'a str,
    /// Ledger identity, second key.
    pub task_id: &'a str,
    /// Live trace/cancellation hooks (None: no supervision — the classic
    /// fire-and-wait behaviour).
    pub observer: Option<&'a dyn TuneObserver>,
}

/// Tune one task, measuring through the caller's engine.
pub fn tune_task_with(
    engine: &eval::Engine,
    space: &ConfigSpace,
    strategy: &mut dyn Strategy,
    budget: TuneBudget,
) -> anyhow::Result<TaskTuneResult> {
    tune_task_tenant(engine, space, strategy, budget, None)
}

/// Modeled testbed seconds one measurement result costs (overhead +
/// repeats × runtime; a flat timeout for invalid configurations). A pure
/// function of the deterministic result, so every tenant planning the
/// same point is debited identically.
fn modeled_cost(budget: &TuneBudget, r: &MeasureResult) -> f64 {
    if r.valid {
        budget.measure_overhead_secs + budget.measure_repeats as f64 * r.seconds
    } else {
        budget.invalid_timeout_secs
    }
}

/// Outcome of screening one admitted batch: the simulator-bound points
/// (`kept`, in original plan order, each flagged if it rode the
/// exploration slice) and the filtered-out remainder paired with its
/// analytical estimate (fed back to the strategy as low-fidelity
/// observations).
struct ScreenSplit {
    kept: Vec<PointConfig>,
    /// Parallel to `kept`: `true` for exploration-slice points — rejected
    /// by rank but measured anyway.
    explore_flags: Vec<bool>,
    rejected: Vec<(PointConfig, MeasureResult)>,
}

/// Score `plan` with the calibrated analytical model and split it into
/// the simulator-bound fraction and the screened-out remainder.
///
/// Ranking mirrors the loop's best-point selection: valid-and-within-area
/// first, then valid-over-area (still useful cost-model signal), then
/// invalid; within a class by predicted seconds ascending, with original
/// plan order breaking ties so the split is deterministic. `ceil(keep·n)`
/// points survive by rank (never fewer than one), and an ε-greedy slice
/// of `ceil(explore · n_keep)` more is drawn uniformly from the rejected
/// tail with a per-iteration deterministic RNG — the insurance that a
/// miscalibrated model cannot permanently lock out a region it misranks.
fn screen_batch(
    space: &ConfigSpace,
    plan: Vec<PointConfig>,
    calib: &eval::Calibration,
    task_id: &str,
    keep: f64,
    explore: f64,
    area_budget_mm2: f64,
    iteration: usize,
) -> ScreenSplit {
    let n = plan.len();
    let overlaps = calib.overlaps(task_id);
    let scored: Vec<MeasureResult> = plan
        .iter()
        .map(|p| eval::AnalyticalBackend::measure_with_overlaps(space, p, overlaps))
        .collect();
    let rank_class = |r: &MeasureResult| -> u8 {
        if r.valid && r.area_mm2 <= area_budget_mm2 {
            0
        } else if r.valid {
            1
        } else {
            2
        }
    };
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        rank_class(&scored[a])
            .cmp(&rank_class(&scored[b]))
            .then_with(|| scored[a].seconds.total_cmp(&scored[b].seconds))
            .then_with(|| a.cmp(&b))
    });
    let n_keep = ((keep * n as f64).ceil() as usize).clamp(1, n);
    let n_explore = if n_keep < n {
        ((explore * n_keep as f64).ceil() as usize).min(n - n_keep)
    } else {
        0
    };
    let mut keep_mask = vec![false; n];
    let mut explore_mask = vec![false; n];
    for &i in &order[..n_keep] {
        keep_mask[i] = true;
    }
    if n_explore > 0 {
        // Partial Fisher-Yates over the rejected tail: after `n_explore`
        // swaps its first slots hold a uniform sample. Seeded per
        // iteration so identical runs screen identically.
        let mut rng = Pcg32::new(0x5c4e_e21b, iteration as u64);
        let tail = &mut order[n_keep..];
        for k in 0..n_explore {
            let j = k + rng.gen_range(tail.len() - k);
            tail.swap(k, j);
            keep_mask[tail[k]] = true;
            explore_mask[tail[k]] = true;
        }
    }
    let total_kept = n_keep + n_explore;
    let mut kept = Vec::with_capacity(total_kept);
    let mut explore_flags = Vec::with_capacity(total_kept);
    let mut rejected = Vec::with_capacity(n - total_kept);
    for (i, (p, r)) in plan.into_iter().zip(scored).enumerate() {
        if keep_mask[i] {
            kept.push(p);
            explore_flags.push(explore_mask[i]);
        } else {
            rejected.push((p, r));
        }
    }
    ScreenSplit { kept, explore_flags, rejected }
}

/// [`tune_task_with`] as one tenant of a shared multi-tenant run: batches
/// queue on the tenant's dispatcher (so competing jobs interleave instead
/// of monopolizing the fleet) and, when a ledger is present, every batch
/// is charged against the (framework, task) allowance *before it is
/// submitted* — the plan is truncated to what the ledger admits, so even
/// a deep pipeline of in-flight batches can never overshoot.
///
/// With `budget.pipeline_depth >= 2` (clamped to the strategy's
/// [`Strategy::max_pipeline_depth`]) the loop keeps up to that many
/// batches in flight at once, planning the next batch while earlier ones
/// measure; dispatcher admission permits are held per in-flight batch
/// (released by the measurement worker the moment the batch completes),
/// not per tenant turn. Depth 1 reproduces the classic serial loop
/// bit-identically.
///
/// `Err` is a whole-fleet outage surfacing from the engine
/// ([`crate::eval::FleetLostError`]): every in-flight batch is drained
/// first — batches that completed before the loss are still settled on
/// the ledger — and points charged for batches that never returned stay
/// charged-but-unsettled (honest accounting — nobody got numbers for
/// them). The run then fails cleanly.
pub fn tune_task_tenant(
    engine: &eval::Engine,
    space: &ConfigSpace,
    strategy: &mut dyn Strategy,
    budget: TuneBudget,
    tenant: Option<&TenantContext>,
) -> anyhow::Result<TaskTuneResult> {
    let requested = budget.pipeline_depth.max(1);
    let depth = requested.min(strategy.max_pipeline_depth().max(1));
    if depth < requested {
        crate::log_info!(
            "tuner",
            "{}: pipeline depth {requested} clamped to {depth} (strategy maximum)",
            strategy.name()
        );
    }
    let sw = Stopwatch::start();
    let mut timer = PhaseTimer::new();
    let mut best = MeasureResult {
        seconds: f64::INFINITY,
        cycles: 0,
        gflops: 0.0,
        area_mm2: 0.0,
        occupancy: 0.0,
        valid: false,
    };
    let mut best_point: Option<PointConfig> = None;
    let mut trace = Vec::new();
    let mut measured = 0usize; // points observed (drained)
    let mut submitted = 0usize; // points charged and in flight or drained
    let mut fresh = 0usize;
    let mut cache_served = 0usize;
    let mut invalid = 0usize;
    let mut iteration = 0usize; // planning iterations started
    let mut modeled_hw_secs = 0.0f64;
    let mut screened = 0usize; // points filtered out by the screening stage
    let mut explore_hits = 0usize; // exploration points that improved best
    let mut screen_secs = 0.0f64; // modeled cost of the screened points
    let mut ordinal = 0usize; // trace ordinal across both fidelities
    let mut stopped = false; // the strategy (or its ledger) ended the run
    let mut failure: Option<anyhow::Error> = None;
    // Screening needs the engine's online calibration (created on first
    // use and shared by every tenant of the engine) and the task identity
    // its per-task fits are keyed by. Exact mode touches neither.
    let calibration = if budget.fidelity.is_screen() {
        Some(engine.ensure_calibration())
    } else {
        None
    };
    let screen_task_id = space.task.short_id();

    /// One admitted batch: still measuring in the background, or already
    /// measured inline (the depth-1 serial path, which pays no worker
    /// spawn).
    enum Inflight<'scope> {
        Pending(eval::PendingBatch<'scope>),
        Ready(anyhow::Result<eval::PairedBatch>),
    }

    std::thread::scope(|scope| {
        // In-flight batches in submission order (front = oldest), each
        // tagged with the planning iteration that produced it.
        let mut inflight: VecDeque<(Inflight<'_>, usize, Vec<bool>)> = VecDeque::new();
        loop {
            // Refill: plan and submit until the pipeline is full, the
            // budget is committed, or the strategy stops. At depth 1 this
            // admits exactly one batch per turn — the serial loop.
            while !stopped
                && failure.is_none()
                && inflight.len() < depth
                && submitted < budget.total_measurements
                && iteration < budget.max_iterations
            {
                if let Some(o) = tenant.and_then(|t| t.observer) {
                    // Cooperative cancellation rides the early-stop path:
                    // nothing new is planned or charged, and the drain
                    // below settles whatever is already in flight.
                    if o.cancelled() {
                        crate::log_debug!(
                            "tuner",
                            "{} cancelled at {submitted}",
                            strategy.name()
                        );
                        stopped = true;
                        break;
                    }
                }
                let want = budget.batch.min(budget.total_measurements - submitted);
                let mut plan = timer.time("plan", || strategy.plan(want));
                if plan.len() > want {
                    // Strategies are asked for *up to* `want` points; one
                    // that over-plans must not breach `total_measurements`.
                    crate::log_debug!(
                        "tuner",
                        "{} planned {} configs for a budget slot of {want}; truncating",
                        strategy.name(),
                        plan.len()
                    );
                    plan.truncate(want);
                }
                if let Some(t) = tenant {
                    if let Some(ledger) = t.ledger {
                        // Charge-before-submit: the allowance is debited
                        // while the batch is still in hand, so in-flight
                        // work is always covered by the ledger.
                        let admitted = ledger.charge(t.framework, t.task_id, plan.len());
                        plan.truncate(admitted);
                    }
                }
                if plan.is_empty() {
                    crate::log_debug!(
                        "tuner",
                        "{} stopped early at {submitted}",
                        strategy.name()
                    );
                    stopped = true;
                    break;
                }
                // The whole admitted batch counts against the measurement
                // budget whichever fidelity evaluates each point — the
                // screened remainder was planned, charged and answered
                // too, just more cheaply.
                let admitted_len = plan.len();
                let mut explore_flags: Vec<bool> = Vec::new();
                if let (Fidelity::Screen { keep, explore }, Some(calib)) =
                    (budget.fidelity, &calibration)
                {
                    let split = timer.time("screen", || {
                        screen_batch(
                            space,
                            plan,
                            calib.as_ref(),
                            &screen_task_id,
                            keep,
                            explore,
                            budget.area_budget_mm2,
                            iteration,
                        )
                    });
                    plan = split.kept;
                    explore_flags = split.explore_flags;
                    if !split.rejected.is_empty() {
                        screened += split.rejected.len();
                        engine.note_screened(split.rejected.len());
                        if let Some(t) = tenant {
                            if let Some(ledger) = t.ledger {
                                // The low-fidelity tier pays its own
                                // (modeled) way: already admitted by
                                // `charge` above, its points settle at the
                                // screening cost so equal-budget accounts
                                // stay conserved.
                                ledger.charge_screen(
                                    t.framework,
                                    t.task_id,
                                    split.rejected.len(),
                                    SCREEN_COST_SECS,
                                );
                            }
                        }
                        let at_secs =
                            (sw.elapsed_secs() - timer.total_secs("queue")).max(0.0);
                        for (_, r) in &split.rejected {
                            ordinal += 1;
                            screen_secs += SCREEN_COST_SECS;
                            trace.push(TraceEntry {
                                ordinal,
                                iteration,
                                at_secs,
                                gflops: r.gflops,
                                best_gflops: best.gflops,
                                valid: r.valid,
                                modeled_cum_secs: modeled_hw_secs + screen_secs,
                                fidelity: TraceFidelity::Screened,
                            });
                            if let Some(o) = tenant.and_then(|t| t.observer) {
                                o.on_trace(trace.last().expect("entry just pushed"));
                            }
                        }
                        timer.time("observe", || {
                            strategy.observe_low_fidelity(&split.rejected)
                        });
                    }
                }
                // Queueing behind competing tenants is scheduling, not
                // search compute: time it as its own phase and keep it out
                // of this job's wall clock, so the concurrent driver
                // reports the same search/compile seconds the serial
                // driver would. But with our OWN batches in flight
                // (depth >= 2), blocking here is a pipeline stall waiting
                // on measurement capacity — real hardware wait the serial
                // loop would have booked under "measure" — so it must not
                // be subtracted from this job's clock.
                let checkout_phase = if inflight.is_empty() { "queue" } else { "measure" };
                let permit = timer.time(checkout_phase, || {
                    tenant.map(|t| {
                        // Fleet capacity moves (shard death/revival):
                        // re-read it so admission tracks how many batches
                        // can really run at once.
                        t.dispatcher.set_slots(engine.concurrent_batch_capacity());
                        t.dispatcher.checkout()
                    })
                });
                submitted += admitted_len;
                let batch_entry = if depth == 1 {
                    // Serial mode measures inline on this thread — no
                    // worker spawn, no space clone: byte-for-byte the
                    // classic loop's hot path. The permit is released the
                    // moment the engine returns, as on the async path.
                    Inflight::Ready(timer.time("measure", || {
                        let out = engine.try_measure_paired(space, plan);
                        drop(permit);
                        out
                    }))
                } else {
                    // The permit travels with the batch and is released by
                    // the measurement worker the moment the batch
                    // completes — held per in-flight batch, not per
                    // tenant turn.
                    Inflight::Pending(engine.submit_batch(scope, space, plan, permit))
                };
                inflight.push_back((batch_entry, iteration, explore_flags));
                iteration += 1;
            }

            // Drain the oldest in-flight batch. Completion is consumed in
            // submission order, so trace ordinals stay in order whatever
            // the engine's internal timing.
            let Some((entry, batch_iteration, explore_flags)) = inflight.pop_front() else {
                break;
            };
            let waited = match entry {
                Inflight::Ready(out) => out,
                Inflight::Pending(pending) => timer.time("measure", || pending.wait()),
            };
            let batch = match waited {
                Ok(batch) => batch,
                Err(e) => {
                    // First failure wins; keep draining so batches that
                    // did complete are settled honestly on the ledger.
                    if failure.is_none() {
                        failure = Some(e);
                    }
                    continue;
                }
            };
            if failure.is_some() {
                // The run is already dead: settle the ledger for this
                // completed batch (its points were charged and measured),
                // but the discarded result is neither traced nor observed.
                if let Some(t) = tenant {
                    if let Some(ledger) = t.ledger {
                        let cost: f64 =
                            batch.pairs.iter().map(|(_, r)| modeled_cost(&budget, r)).sum();
                        ledger.settle(t.framework, t.task_id, &batch.origins, cost);
                    }
                }
                continue;
            }
            let modeled_before = modeled_hw_secs;
            // Stamp trace entries on the queue-excluded clock (the same
            // clock as `wall_secs`), not the raw stopwatch: dispatcher
            // queue wait is scheduling, and leaving it in shifted
            // concurrent-driver Fig. 6 curves right of the serial ones.
            let at_secs = (sw.elapsed_secs() - timer.total_secs("queue")).max(0.0);
            for (idx, ((p, r), origin)) in batch.pairs.iter().zip(&batch.origins).enumerate() {
                measured += 1;
                ordinal += 1;
                if origin.is_fresh() {
                    fresh += 1;
                } else {
                    cache_served += 1;
                }
                if !r.valid {
                    invalid += 1;
                }
                modeled_hw_secs += modeled_cost(&budget, r);
                if r.valid && r.area_mm2 <= budget.area_budget_mm2 && r.seconds < best.seconds {
                    best = *r;
                    best_point = Some(p.clone());
                    if explore_flags.get(idx).copied().unwrap_or(false) {
                        // A point the analytical filter rejected just beat
                        // everything it kept — the screening model is
                        // misranking this task. The exploration slice
                        // exists precisely to surface (and recover from)
                        // this.
                        explore_hits += 1;
                        crate::log_info!(
                            "tuner",
                            "{}: exploration point improved best \
                             (explore_hits={explore_hits}) — screening \
                             misranked it",
                            strategy.name()
                        );
                    }
                }
                trace.push(TraceEntry {
                    ordinal,
                    iteration: batch_iteration,
                    at_secs,
                    gflops: r.gflops,
                    best_gflops: best.gflops,
                    valid: r.valid,
                    modeled_cum_secs: modeled_hw_secs + screen_secs,
                    fidelity: TraceFidelity::Exact,
                });
                if let Some(o) = tenant.and_then(|t| t.observer) {
                    o.on_trace(trace.last().expect("entry just pushed"));
                }
            }
            if let Some(t) = tenant {
                if let Some(ledger) = t.ledger {
                    // Same debit whoever measured first: the modeled cost
                    // is a pure function of the (deterministic) results.
                    ledger.settle(
                        t.framework,
                        t.task_id,
                        &batch.origins,
                        modeled_hw_secs - modeled_before,
                    );
                }
            }
            timer.time("observe", || strategy.observe(&batch.pairs));
        }
    });

    if let Some(e) = failure {
        return Err(e);
    }

    Ok(TaskTuneResult {
        best_point,
        best,
        measurements: measured,
        fresh,
        cache_served,
        invalid,
        wall_secs: (sw.elapsed_secs() - timer.total_secs("queue")).max(0.0),
        modeled_hw_secs,
        screened,
        explore_hits,
        trace,
        timer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;
    use std::collections::HashSet;

    /// Trivially random strategy used to exercise the loop.
    struct RandomProbe {
        space: ConfigSpace,
        rng: Pcg32,
        seen: HashSet<usize>,
        observed: usize,
    }

    impl Strategy for RandomProbe {
        fn name(&self) -> &'static str {
            "probe"
        }
        fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
            let mut out = Vec::new();
            let mut attempts = 0;
            while out.len() < batch && attempts < batch * 50 {
                let p = self.space.random_point(&mut self.rng);
                if self.seen.insert(self.space.flat_index(&p)) {
                    out.push(p);
                }
                attempts += 1;
            }
            out
        }
        fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
            self.observed += results.len();
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
    }

    #[test]
    fn respects_budget_and_finds_something() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(1),
            seen: HashSet::new(),
            observed: 0,
        };
        let budget = TuneBudget { total_measurements: 100, batch: 32, workers: 2, ..Default::default() };
        let r = tune_task(&s, &mut strat, budget).unwrap();
        assert_eq!(r.measurements, 100);
        assert_eq!(strat.observed, 100);
        assert!(r.best_point.is_some());
        assert!(r.best.valid);
        assert!(r.best_seconds().is_finite());
        assert_eq!(r.trace.len(), 100);
    }

    #[test]
    fn trace_best_is_monotone() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(2),
            seen: HashSet::new(),
            observed: 0,
        };
        let r = tune_task(&s, &mut strat, TuneBudget { total_measurements: 64, batch: 16, workers: 2, ..Default::default() }).unwrap();
        for w in r.trace.windows(2) {
            assert!(w[1].best_gflops >= w[0].best_gflops);
            assert_eq!(w[1].ordinal, w[0].ordinal + 1);
        }
    }

    #[test]
    fn empty_plan_stops_early() {
        struct Dead;
        impl Strategy for Dead {
            fn name(&self) -> &'static str {
                "dead"
            }
            fn plan(&mut self, _batch: usize) -> Vec<PointConfig> {
                Vec::new()
            }
            fn observe(&mut self, _results: &[(PointConfig, MeasureResult)]) {}
        }
        let s = space();
        let r = tune_task(&s, &mut Dead, TuneBudget::default()).unwrap();
        assert_eq!(r.measurements, 0);
        assert!(r.best_point.is_none());
    }

    #[test]
    fn shared_engine_dedups_across_runs() {
        let s = space();
        let engine = crate::eval::Engine::vta_sim(2);
        let budget =
            TuneBudget { total_measurements: 48, batch: 16, workers: 2, ..Default::default() };
        let run = |engine: &crate::eval::Engine| {
            let mut strat = RandomProbe {
                space: s.clone(),
                rng: Pcg32::seeded(4),
                seen: HashSet::new(),
                observed: 0,
            };
            tune_task_with(engine, &s, &mut strat, budget).unwrap()
        };
        let a = run(&engine);
        let sims_after_first = engine.stats().simulations;
        assert_eq!(sims_after_first, 48);
        let b = run(&engine);
        assert_eq!(a.best.seconds, b.best.seconds);
        // Same seed → same plan → the second run is fully cache-served.
        assert_eq!(engine.stats().simulations, sims_after_first);
        assert!(engine.stats().cache_hits >= 48);
    }

    /// A strategy that ignores the requested batch size and plans three
    /// times as many points — the over-planning bug's trigger.
    struct OverPlanner {
        inner: RandomProbe,
    }

    impl Strategy for OverPlanner {
        fn name(&self) -> &'static str {
            "overplanner"
        }
        fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
            self.inner.plan(batch * 3)
        }
        fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
            self.inner.observe(results);
        }
    }

    #[test]
    fn over_planning_strategy_cannot_breach_the_budget() {
        let s = space();
        let mut strat = OverPlanner {
            inner: RandomProbe {
                space: s.clone(),
                rng: Pcg32::seeded(6),
                seen: HashSet::new(),
                observed: 0,
            },
        };
        let budget =
            TuneBudget { total_measurements: 40, batch: 16, workers: 2, ..Default::default() };
        let r = tune_task(&s, &mut strat, budget).unwrap();
        assert_eq!(r.measurements, 40, "plan truncation must land exactly on the budget");
        assert_eq!(r.trace.len(), 40);
        assert_eq!(r.trace.last().unwrap().ordinal, 40);
        // The strategy only observes what was actually measured.
        assert_eq!(strat.inner.observed, 40);
    }

    #[test]
    fn degenerate_parity_target_is_never_reached() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(8),
            seen: HashSet::new(),
            observed: 0,
        };
        let budget =
            TuneBudget { total_measurements: 16, batch: 8, workers: 2, ..Default::default() };
        let r = tune_task(&s, &mut strat, budget).unwrap();
        assert!(r.modeled_hw_secs > 0.0);
        // A zero/negative/NaN target (missing or empty baseline) charges
        // the full modeled time instead of "parity at the first entry".
        assert_eq!(r.modeled_secs_to_quality(0.0), r.modeled_hw_secs);
        assert_eq!(r.modeled_secs_to_quality(-1.0), r.modeled_hw_secs);
        assert_eq!(r.modeled_secs_to_quality(f64::NAN), r.modeled_hw_secs);
        // A real (positive) target is still reachable mid-trace.
        let reached = r.trace.last().unwrap().best_gflops;
        if reached > 0.0 {
            assert!(r.modeled_secs_to_quality(reached * 0.5) <= r.modeled_hw_secs);
        }
    }

    #[test]
    fn provenance_counts_cover_every_measurement() {
        let s = space();
        let engine = crate::eval::Engine::vta_sim(2);
        let budget =
            TuneBudget { total_measurements: 32, batch: 16, workers: 2, ..Default::default() };
        let run = |engine: &crate::eval::Engine, seed: u64| {
            let mut strat = RandomProbe {
                space: s.clone(),
                rng: Pcg32::seeded(seed),
                seen: HashSet::new(),
                observed: 0,
            };
            tune_task_with(engine, &s, &mut strat, budget).unwrap()
        };
        let a = run(&engine, 12);
        assert_eq!(a.fresh + a.cache_served, a.measurements);
        assert_eq!(a.fresh, a.measurements, "first run on a cold cache is all fresh");
        // The identical run replays from the cache: same debit, no
        // simulator time — the "measure once, charge everyone" split.
        let b = run(&engine, 12);
        assert_eq!(b.measurements, a.measurements);
        assert_eq!(b.fresh, 0);
        assert_eq!(b.cache_served, b.measurements);
    }

    #[test]
    fn fidelity_strings_parse_and_roundtrip() {
        assert_eq!(Fidelity::parse("exact"), Some(Fidelity::Exact));
        let short = Fidelity::parse("screen:0.25").unwrap();
        assert_eq!(short, Fidelity::Screen { keep: 0.25, explore: DEFAULT_EXPLORE_FRAC });
        let full = Fidelity::parse("screen:0.5:0").unwrap();
        assert_eq!(full, Fidelity::Screen { keep: 0.5, explore: 0.0 });
        for f in [Fidelity::Exact, short, full] {
            assert_eq!(Fidelity::parse(&f.describe()), Some(f), "{}", f.describe());
        }
        for bad in
            ["", "screen", "screen:", "screen:0", "screen:1.5", "screen:0.5:2", "screen:-1", "sim"]
        {
            assert!(Fidelity::parse(bad).is_none(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn exact_mode_reports_no_screening() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(19),
            seen: HashSet::new(),
            observed: 0,
        };
        let budget =
            TuneBudget { total_measurements: 32, batch: 16, workers: 2, ..Default::default() };
        assert_eq!(budget.fidelity, Fidelity::Exact, "exact is the default");
        let r = tune_task(&s, &mut strat, budget).unwrap();
        assert_eq!(r.screened, 0);
        assert_eq!(r.explore_hits, 0);
        assert!(r.trace.iter().all(|e| e.fidelity == TraceFidelity::Exact));
    }

    #[test]
    fn screening_filters_most_points_and_tags_the_trace() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(17),
            seen: HashSet::new(),
            observed: 0,
        };
        let budget = TuneBudget {
            total_measurements: 96,
            batch: 32,
            workers: 2,
            fidelity: Fidelity::Screen { keep: 0.25, explore: 0.1 },
            ..Default::default()
        };
        let r = tune_task(&s, &mut strat, budget).unwrap();
        // The whole admitted budget is accounted: measured + screened.
        assert!(r.screened > 0);
        assert_eq!(r.measurements + r.screened, 96);
        // keep=0.25 plus a 10% exploration slice forwards ~28% per batch.
        assert!(
            r.measurements <= 96 / 2,
            "screening should filter most points, measured {}",
            r.measurements
        );
        assert!(r.best.valid, "the kept fraction still finds a valid best");
        // The trace interleaves both tiers with contiguous ordinals.
        assert_eq!(r.trace.len(), 96);
        for (i, e) in r.trace.iter().enumerate() {
            assert_eq!(e.ordinal, i + 1);
        }
        let tagged = r.trace.iter().filter(|e| e.fidelity == TraceFidelity::Screened).count();
        assert_eq!(tagged, r.screened);
        // Only real measurements reach the exact-observation channel.
        assert_eq!(strat.observed, r.measurements);
        // Cumulative modeled time stays monotone across the mixed trace.
        for w in r.trace.windows(2) {
            assert!(w[1].modeled_cum_secs >= w[0].modeled_cum_secs);
        }
    }

    #[test]
    fn screened_points_reach_the_low_fidelity_channel() {
        struct LowFi {
            inner: RandomProbe,
            low: usize,
        }
        impl Strategy for LowFi {
            fn name(&self) -> &'static str {
                "lowfi"
            }
            fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
                self.inner.plan(batch)
            }
            fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
                self.inner.observe(results);
            }
            fn observe_low_fidelity(&mut self, results: &[(PointConfig, MeasureResult)]) {
                self.low += results.len();
                // Screening estimates are finite numbers a posterior could
                // actually use (invalid ones carry gflops 0, like the
                // exact channel).
                for (_, r) in results {
                    assert!(r.gflops.is_finite());
                }
            }
        }
        let s = space();
        let mut strat = LowFi {
            inner: RandomProbe {
                space: s.clone(),
                rng: Pcg32::seeded(23),
                seen: HashSet::new(),
                observed: 0,
            },
            low: 0,
        };
        let budget = TuneBudget {
            total_measurements: 64,
            batch: 32,
            workers: 2,
            fidelity: Fidelity::Screen { keep: 0.5, explore: 0.0 },
            ..Default::default()
        };
        let r = tune_task(&s, &mut strat, budget).unwrap();
        assert_eq!(strat.low, r.screened);
        assert_eq!(strat.inner.observed, r.measurements);
    }

    #[test]
    fn screen_split_is_deterministic_and_orders_by_predicted_rank() {
        let s = space();
        let calib = crate::eval::Calibration::new(crate::eval::Fingerprint::current());
        let mut rng = Pcg32::seeded(31);
        let mut seen = HashSet::new();
        let mut plan = Vec::new();
        while plan.len() < 40 {
            let p = s.random_point(&mut rng);
            if seen.insert(s.flat_index(&p)) {
                plan.push(p);
            }
        }
        let task_id = s.task.short_id();
        let area = crate::vta::area::default_area_budget_mm2();
        let split =
            screen_batch(&s, plan.clone(), &calib, &task_id, 0.25, 0.1, area, 7);
        let again =
            screen_batch(&s, plan.clone(), &calib, &task_id, 0.25, 0.1, area, 7);
        assert_eq!(split.kept, again.kept, "same iteration seed → same split");
        assert_eq!(split.explore_flags, again.explore_flags);
        // ceil(0.25·40)=10 by rank + ceil(0.1·10)=1 exploration point.
        assert_eq!(split.kept.len(), 11);
        assert_eq!(split.explore_flags.iter().filter(|&&e| e).count(), 1);
        assert_eq!(split.rejected.len(), 40 - 11);
        assert_eq!(split.kept.len(), split.explore_flags.len());
        // The best predicted point is never screened out.
        let overlaps = calib.overlaps(&task_id);
        let best_pred = plan
            .iter()
            .map(|p| crate::eval::AnalyticalBackend::measure_with_overlaps(&s, p, overlaps))
            .enumerate()
            .filter(|(_, r)| r.valid && r.area_mm2 <= area)
            .min_by(|(_, a), (_, b)| a.seconds.total_cmp(&b.seconds))
            .map(|(i, _)| plan[i].clone());
        if let Some(bp) = best_pred {
            assert!(split.kept.contains(&bp));
        }
        // A keep fraction of 1 screens nothing.
        let all = screen_batch(&s, plan.clone(), &calib, &task_id, 1.0, 0.5, area, 7);
        assert_eq!(all.kept.len(), 40);
        assert!(all.rejected.is_empty());
        assert!(all.explore_flags.iter().all(|&e| !e));
    }

    #[test]
    fn timer_tracks_phases() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(3),
            seen: HashSet::new(),
            observed: 0,
        };
        let r = tune_task(&s, &mut strat, TuneBudget { total_measurements: 32, batch: 16, workers: 1, ..Default::default() }).unwrap();
        assert!(r.timer.count("plan") >= 2);
        assert!(r.timer.count("measure") >= 2);
        assert!(r.timer.count("observe") >= 2);
    }
}
