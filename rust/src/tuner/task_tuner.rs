//! Per-task tuning loop: budgeted plan → batched engine measure → observe.
//!
//! Two execution shapes share one implementation:
//!
//! - **Serial** (`pipeline_depth == 1`, the paper-faithful default): one
//!   batch at a time — every plan sees every earlier result, reproducing
//!   the classic lockstep loop bit for bit.
//! - **Pipelined** (`pipeline_depth >= 2`, the speed mode): batch *k* is
//!   submitted to the engine asynchronously
//!   ([`eval::Engine::submit_batch`]) and, while it is in flight, the
//!   strategy already plans batch *k+1* from its current posterior.
//!   Completions drain strictly in submission order, so trace ordinals
//!   stay in order; the ledger is charged *before* each submission, so an
//!   in-flight pipeline can never overshoot a budget; and both a strategy
//!   early-stop and a lost measurement fleet drain every in-flight batch
//!   before the loop returns. On a remote fleet this hides the search
//!   compute behind measurement RTT — wall-clock approaches
//!   `max(search, measure)` instead of their sum.

use super::strategy::Strategy;
use crate::eval::{self, BudgetLedger, Dispatcher, MeasureResult};
use crate::space::{ConfigSpace, PointConfig};
use crate::util::timer::{PhaseTimer, Stopwatch};
use std::collections::VecDeque;

/// Measurement budget (Table 4/5: Σb = 1000, b = 64).
#[derive(Debug, Clone, Copy)]
pub struct TuneBudget {
    /// Total hardware measurements allowed.
    pub total_measurements: usize,
    /// Measurements per iteration (planning batch).
    pub batch: usize,
    /// Worker threads for parallel simulation. Only consulted when
    /// [`tune_task`] builds its own default engine; an engine passed to
    /// [`tune_task_with`] brings its own worker pool.
    pub workers: usize,
    /// Area feasibility ceiling (mm²) for the *final* configuration:
    /// configurations above it are measured (they inform the cost model)
    /// but can never be selected as best — an over-budget accelerator is
    /// not implementable (Eq. 4's hard form).
    pub area_budget_mm2: f64,
    /// Planning iterations allowed (Table 4's iteration_opt=16). Strategies
    /// that plan fewer configs per iteration (ARCO's Confidence Sampling)
    /// therefore spend fewer total hardware measurements.
    pub max_iterations: usize,
    /// Modeled cost of one hardware measurement on a real testbed:
    /// fixed setup/transfer overhead (s)...
    pub measure_overhead_secs: f64,
    /// ...plus `repeats` timed runs of the configuration...
    pub measure_repeats: usize,
    /// ...and a timeout charge for invalid configurations (a build/run
    /// failure still wastes wall-clock on real hardware).
    pub invalid_timeout_secs: f64,
    /// Measurement batches the loop may have in flight at once
    /// (`--pipeline-depth`). `1` (default) is the paper-faithful serial
    /// loop: plan, measure, observe, repeat — reproduced bit-identically.
    /// `>= 2` is the speed mode: the strategy plans batch *k+1* while
    /// batch *k* is still on the hardware, trading posterior freshness
    /// (observations arrive up to `depth - 1` batches late) for
    /// wall-clock. Clamped to [`Strategy::max_pipeline_depth`]; values
    /// below 1 behave as 1.
    pub pipeline_depth: usize,
}

impl Default for TuneBudget {
    fn default() -> Self {
        TuneBudget {
            total_measurements: 1000,
            batch: 64,
            workers: crate::util::pool::default_workers(),
            area_budget_mm2: crate::vta::area::default_area_budget_mm2(),
            max_iterations: 16,
            measure_overhead_secs: 0.05,
            measure_repeats: 10,
            invalid_timeout_secs: 1.0,
            pipeline_depth: 1,
        }
    }
}

/// One measured configuration in the tuning trace (Fig. 4 / Fig. 7 data).
#[derive(Debug, Clone)]
pub struct TraceEntry {
    /// Measurement ordinal (1-based).
    pub ordinal: usize,
    /// Iteration the measurement belonged to.
    pub iteration: usize,
    /// Seconds of *this job's* clock when this was measured: wall-clock
    /// since tuning started minus time spent queued behind competing
    /// tenants at the dispatcher — the same queue-excluded clock as
    /// [`TaskTuneResult::wall_secs`], so concurrent-driver convergence
    /// curves (Fig. 6) line up with the serial driver's instead of
    /// shifting right by arrival-order-dependent scheduling wait.
    pub at_secs: f64,
    /// Achieved GFLOPS (0 for invalid configs).
    pub gflops: f64,
    /// Best GFLOPS so far (running max).
    pub best_gflops: f64,
    /// Whether the config was valid.
    pub valid: bool,
    /// Cumulative *modeled* hardware-measurement time (s) up to and
    /// including this measurement (see `TuneBudget::measure_overhead_secs`).
    pub modeled_cum_secs: f64,
}

/// Outcome of tuning one task.
#[derive(Debug, Clone)]
pub struct TaskTuneResult {
    pub best_point: Option<PointConfig>,
    pub best: MeasureResult,
    pub measurements: usize,
    /// Of `measurements`, points whose simulation actually ran for this
    /// job (see [`crate::eval::Origin`]).
    pub fresh: usize,
    /// Of `measurements`, points served from shared state (cache, dedup,
    /// coalescing, fleet shard caches) — same debit, no simulator time.
    pub cache_served: usize,
    pub invalid: usize,
    /// Wall-clock of this job excluding time spent queued behind competing
    /// tenants at the dispatcher (scheduling wait is not search compute;
    /// without the exclusion a concurrent run would report inflated,
    /// arrival-order-dependent search/compile seconds).
    pub wall_secs: f64,
    /// Modeled wall-clock a real testbed would spend on the hardware
    /// measurements (overhead + repeats x runtime; timeout for invalid) —
    /// the dominant term of "compilation time" in the paper's Fig. 6.
    pub modeled_hw_secs: f64,
    pub trace: Vec<TraceEntry>,
    pub timer: PhaseTimer,
}

impl TaskTuneResult {
    /// Best measured task runtime in seconds (inf if nothing valid).
    pub fn best_seconds(&self) -> f64 {
        self.best.seconds
    }

    /// Modeled time (s) until the running best first reached
    /// `target_gflops` — the time-to-quality metric behind Fig. 6.
    /// Returns the full modeled time if the target was never reached.
    ///
    /// A non-positive (or NaN) target is degenerate — it usually means the
    /// baseline found nothing valid — and is treated as *never reached*:
    /// otherwise the very first trace entry, even an invalid config with
    /// `best_gflops == 0`, would "reach parity" instantly and make the
    /// time-to-parity comparison meaningless.
    pub fn modeled_secs_to_quality(&self, target_gflops: f64) -> f64 {
        if target_gflops <= 0.0 || target_gflops.is_nan() {
            return self.modeled_hw_secs;
        }
        for e in &self.trace {
            if e.best_gflops >= target_gflops {
                return e.modeled_cum_secs;
            }
        }
        self.modeled_hw_secs
    }
}

/// Tune one task with a strategy under a budget, using a private default
/// measurement engine (cycle simulator backend, in-memory cache,
/// `budget.workers` threads). Prefer [`tune_task_with`] and a shared
/// [`eval::Engine`] when tuning several tasks or frameworks: a shared
/// engine pays for each unique configuration at most once across all of
/// them.
///
/// `Err` means the measurement infrastructure was lost mid-run (a remote
/// fleet with no reachable shard — [`crate::eval::FleetLostError`]); local
/// backends never fail.
pub fn tune_task(
    space: &ConfigSpace,
    strategy: &mut dyn Strategy,
    budget: TuneBudget,
) -> anyhow::Result<TaskTuneResult> {
    let engine = eval::Engine::vta_sim(budget.workers);
    tune_task_with(&engine, space, strategy, budget)
}

/// Live hooks into a running [`tune_task_tenant`] loop, for callers that
/// supervise jobs from outside the loop thread (the `arco serve-tune`
/// daemon). Both methods are called from the tuning thread itself:
/// `on_trace` once per trace entry the moment it is appended (in ordinal
/// order), and `cancelled` once per refill turn. A `true` from `cancelled`
/// ends the run through the normal early-stop path — in-flight batches
/// drain, completed ones settle on the ledger, and the partial
/// [`TaskTuneResult`] is returned intact.
pub trait TuneObserver {
    /// A trace entry was just appended (entries arrive in ordinal order).
    fn on_trace(&self, _entry: &TraceEntry) {}
    /// Polled between batches; `true` requests a cooperative early stop.
    fn cancelled(&self) -> bool {
        false
    }
}

/// Multi-tenant identity of one tuning job: who it is (for ledger
/// accounting) and which shared scheduling infrastructure its measurement
/// batches go through. Built by the concurrent comparison driver
/// ([`crate::tuner::compare`]); standalone runs pass `None` and keep the
/// classic single-tenant behaviour.
pub struct TenantContext<'a> {
    /// Equal-budget ledger charged before every batch (None: the
    /// dispatcher still interleaves, but only the local budget applies).
    pub ledger: Option<&'a BudgetLedger>,
    /// FIFO admission of measurement batches across competing jobs.
    pub dispatcher: &'a Dispatcher,
    /// Ledger identity, first key.
    pub framework: &'a str,
    /// Ledger identity, second key.
    pub task_id: &'a str,
    /// Live trace/cancellation hooks (None: no supervision — the classic
    /// fire-and-wait behaviour).
    pub observer: Option<&'a dyn TuneObserver>,
}

/// Tune one task, measuring through the caller's engine.
pub fn tune_task_with(
    engine: &eval::Engine,
    space: &ConfigSpace,
    strategy: &mut dyn Strategy,
    budget: TuneBudget,
) -> anyhow::Result<TaskTuneResult> {
    tune_task_tenant(engine, space, strategy, budget, None)
}

/// Modeled testbed seconds one measurement result costs (overhead +
/// repeats × runtime; a flat timeout for invalid configurations). A pure
/// function of the deterministic result, so every tenant planning the
/// same point is debited identically.
fn modeled_cost(budget: &TuneBudget, r: &MeasureResult) -> f64 {
    if r.valid {
        budget.measure_overhead_secs + budget.measure_repeats as f64 * r.seconds
    } else {
        budget.invalid_timeout_secs
    }
}

/// [`tune_task_with`] as one tenant of a shared multi-tenant run: batches
/// queue on the tenant's dispatcher (so competing jobs interleave instead
/// of monopolizing the fleet) and, when a ledger is present, every batch
/// is charged against the (framework, task) allowance *before it is
/// submitted* — the plan is truncated to what the ledger admits, so even
/// a deep pipeline of in-flight batches can never overshoot.
///
/// With `budget.pipeline_depth >= 2` (clamped to the strategy's
/// [`Strategy::max_pipeline_depth`]) the loop keeps up to that many
/// batches in flight at once, planning the next batch while earlier ones
/// measure; dispatcher admission permits are held per in-flight batch
/// (released by the measurement worker the moment the batch completes),
/// not per tenant turn. Depth 1 reproduces the classic serial loop
/// bit-identically.
///
/// `Err` is a whole-fleet outage surfacing from the engine
/// ([`crate::eval::FleetLostError`]): every in-flight batch is drained
/// first — batches that completed before the loss are still settled on
/// the ledger — and points charged for batches that never returned stay
/// charged-but-unsettled (honest accounting — nobody got numbers for
/// them). The run then fails cleanly.
pub fn tune_task_tenant(
    engine: &eval::Engine,
    space: &ConfigSpace,
    strategy: &mut dyn Strategy,
    budget: TuneBudget,
    tenant: Option<&TenantContext>,
) -> anyhow::Result<TaskTuneResult> {
    let requested = budget.pipeline_depth.max(1);
    let depth = requested.min(strategy.max_pipeline_depth().max(1));
    if depth < requested {
        crate::log_info!(
            "tuner",
            "{}: pipeline depth {requested} clamped to {depth} (strategy maximum)",
            strategy.name()
        );
    }
    let sw = Stopwatch::start();
    let mut timer = PhaseTimer::new();
    let mut best = MeasureResult {
        seconds: f64::INFINITY,
        cycles: 0,
        gflops: 0.0,
        area_mm2: 0.0,
        occupancy: 0.0,
        valid: false,
    };
    let mut best_point: Option<PointConfig> = None;
    let mut trace = Vec::new();
    let mut measured = 0usize; // points observed (drained)
    let mut submitted = 0usize; // points charged and in flight or drained
    let mut fresh = 0usize;
    let mut cache_served = 0usize;
    let mut invalid = 0usize;
    let mut iteration = 0usize; // planning iterations started
    let mut modeled_hw_secs = 0.0f64;
    let mut stopped = false; // the strategy (or its ledger) ended the run
    let mut failure: Option<anyhow::Error> = None;

    /// One admitted batch: still measuring in the background, or already
    /// measured inline (the depth-1 serial path, which pays no worker
    /// spawn).
    enum Inflight<'scope> {
        Pending(eval::PendingBatch<'scope>),
        Ready(anyhow::Result<eval::PairedBatch>),
    }

    std::thread::scope(|scope| {
        // In-flight batches in submission order (front = oldest), each
        // tagged with the planning iteration that produced it.
        let mut inflight: VecDeque<(Inflight<'_>, usize)> = VecDeque::new();
        loop {
            // Refill: plan and submit until the pipeline is full, the
            // budget is committed, or the strategy stops. At depth 1 this
            // admits exactly one batch per turn — the serial loop.
            while !stopped
                && failure.is_none()
                && inflight.len() < depth
                && submitted < budget.total_measurements
                && iteration < budget.max_iterations
            {
                if let Some(o) = tenant.and_then(|t| t.observer) {
                    // Cooperative cancellation rides the early-stop path:
                    // nothing new is planned or charged, and the drain
                    // below settles whatever is already in flight.
                    if o.cancelled() {
                        crate::log_debug!(
                            "tuner",
                            "{} cancelled at {submitted}",
                            strategy.name()
                        );
                        stopped = true;
                        break;
                    }
                }
                let want = budget.batch.min(budget.total_measurements - submitted);
                let mut plan = timer.time("plan", || strategy.plan(want));
                if plan.len() > want {
                    // Strategies are asked for *up to* `want` points; one
                    // that over-plans must not breach `total_measurements`.
                    crate::log_debug!(
                        "tuner",
                        "{} planned {} configs for a budget slot of {want}; truncating",
                        strategy.name(),
                        plan.len()
                    );
                    plan.truncate(want);
                }
                if let Some(t) = tenant {
                    if let Some(ledger) = t.ledger {
                        // Charge-before-submit: the allowance is debited
                        // while the batch is still in hand, so in-flight
                        // work is always covered by the ledger.
                        let admitted = ledger.charge(t.framework, t.task_id, plan.len());
                        plan.truncate(admitted);
                    }
                }
                if plan.is_empty() {
                    crate::log_debug!(
                        "tuner",
                        "{} stopped early at {submitted}",
                        strategy.name()
                    );
                    stopped = true;
                    break;
                }
                // Queueing behind competing tenants is scheduling, not
                // search compute: time it as its own phase and keep it out
                // of this job's wall clock, so the concurrent driver
                // reports the same search/compile seconds the serial
                // driver would. But with our OWN batches in flight
                // (depth >= 2), blocking here is a pipeline stall waiting
                // on measurement capacity — real hardware wait the serial
                // loop would have booked under "measure" — so it must not
                // be subtracted from this job's clock.
                let checkout_phase = if inflight.is_empty() { "queue" } else { "measure" };
                let permit = timer.time(checkout_phase, || {
                    tenant.map(|t| {
                        // Fleet capacity moves (shard death/revival):
                        // re-read it so admission tracks how many batches
                        // can really run at once.
                        t.dispatcher.set_slots(engine.concurrent_batch_capacity());
                        t.dispatcher.checkout()
                    })
                });
                submitted += plan.len();
                let batch_entry = if depth == 1 {
                    // Serial mode measures inline on this thread — no
                    // worker spawn, no space clone: byte-for-byte the
                    // classic loop's hot path. The permit is released the
                    // moment the engine returns, as on the async path.
                    Inflight::Ready(timer.time("measure", || {
                        let out = engine.try_measure_paired(space, plan);
                        drop(permit);
                        out
                    }))
                } else {
                    // The permit travels with the batch and is released by
                    // the measurement worker the moment the batch
                    // completes — held per in-flight batch, not per
                    // tenant turn.
                    Inflight::Pending(engine.submit_batch(scope, space, plan, permit))
                };
                inflight.push_back((batch_entry, iteration));
                iteration += 1;
            }

            // Drain the oldest in-flight batch. Completion is consumed in
            // submission order, so trace ordinals stay in order whatever
            // the engine's internal timing.
            let Some((entry, batch_iteration)) = inflight.pop_front() else {
                break;
            };
            let waited = match entry {
                Inflight::Ready(out) => out,
                Inflight::Pending(pending) => timer.time("measure", || pending.wait()),
            };
            let batch = match waited {
                Ok(batch) => batch,
                Err(e) => {
                    // First failure wins; keep draining so batches that
                    // did complete are settled honestly on the ledger.
                    if failure.is_none() {
                        failure = Some(e);
                    }
                    continue;
                }
            };
            if failure.is_some() {
                // The run is already dead: settle the ledger for this
                // completed batch (its points were charged and measured),
                // but the discarded result is neither traced nor observed.
                if let Some(t) = tenant {
                    if let Some(ledger) = t.ledger {
                        let cost: f64 =
                            batch.pairs.iter().map(|(_, r)| modeled_cost(&budget, r)).sum();
                        ledger.settle(t.framework, t.task_id, &batch.origins, cost);
                    }
                }
                continue;
            }
            let modeled_before = modeled_hw_secs;
            // Stamp trace entries on the queue-excluded clock (the same
            // clock as `wall_secs`), not the raw stopwatch: dispatcher
            // queue wait is scheduling, and leaving it in shifted
            // concurrent-driver Fig. 6 curves right of the serial ones.
            let at_secs = (sw.elapsed_secs() - timer.total_secs("queue")).max(0.0);
            for ((p, r), origin) in batch.pairs.iter().zip(&batch.origins) {
                measured += 1;
                if origin.is_fresh() {
                    fresh += 1;
                } else {
                    cache_served += 1;
                }
                if !r.valid {
                    invalid += 1;
                }
                modeled_hw_secs += modeled_cost(&budget, r);
                if r.valid && r.area_mm2 <= budget.area_budget_mm2 && r.seconds < best.seconds {
                    best = *r;
                    best_point = Some(p.clone());
                }
                trace.push(TraceEntry {
                    ordinal: measured,
                    iteration: batch_iteration,
                    at_secs,
                    gflops: r.gflops,
                    best_gflops: best.gflops,
                    valid: r.valid,
                    modeled_cum_secs: modeled_hw_secs,
                });
                if let Some(o) = tenant.and_then(|t| t.observer) {
                    o.on_trace(trace.last().expect("entry just pushed"));
                }
            }
            if let Some(t) = tenant {
                if let Some(ledger) = t.ledger {
                    // Same debit whoever measured first: the modeled cost
                    // is a pure function of the (deterministic) results.
                    ledger.settle(
                        t.framework,
                        t.task_id,
                        &batch.origins,
                        modeled_hw_secs - modeled_before,
                    );
                }
            }
            timer.time("observe", || strategy.observe(&batch.pairs));
        }
    });

    if let Some(e) = failure {
        return Err(e);
    }

    Ok(TaskTuneResult {
        best_point,
        best,
        measurements: measured,
        fresh,
        cache_served,
        invalid,
        wall_secs: (sw.elapsed_secs() - timer.total_secs("queue")).max(0.0),
        modeled_hw_secs,
        trace,
        timer,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;
    use std::collections::HashSet;

    /// Trivially random strategy used to exercise the loop.
    struct RandomProbe {
        space: ConfigSpace,
        rng: Pcg32,
        seen: HashSet<usize>,
        observed: usize,
    }

    impl Strategy for RandomProbe {
        fn name(&self) -> &'static str {
            "probe"
        }
        fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
            let mut out = Vec::new();
            let mut attempts = 0;
            while out.len() < batch && attempts < batch * 50 {
                let p = self.space.random_point(&mut self.rng);
                if self.seen.insert(self.space.flat_index(&p)) {
                    out.push(p);
                }
                attempts += 1;
            }
            out
        }
        fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
            self.observed += results.len();
        }
    }

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
    }

    #[test]
    fn respects_budget_and_finds_something() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(1),
            seen: HashSet::new(),
            observed: 0,
        };
        let budget = TuneBudget { total_measurements: 100, batch: 32, workers: 2, ..Default::default() };
        let r = tune_task(&s, &mut strat, budget).unwrap();
        assert_eq!(r.measurements, 100);
        assert_eq!(strat.observed, 100);
        assert!(r.best_point.is_some());
        assert!(r.best.valid);
        assert!(r.best_seconds().is_finite());
        assert_eq!(r.trace.len(), 100);
    }

    #[test]
    fn trace_best_is_monotone() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(2),
            seen: HashSet::new(),
            observed: 0,
        };
        let r = tune_task(&s, &mut strat, TuneBudget { total_measurements: 64, batch: 16, workers: 2, ..Default::default() }).unwrap();
        for w in r.trace.windows(2) {
            assert!(w[1].best_gflops >= w[0].best_gflops);
            assert_eq!(w[1].ordinal, w[0].ordinal + 1);
        }
    }

    #[test]
    fn empty_plan_stops_early() {
        struct Dead;
        impl Strategy for Dead {
            fn name(&self) -> &'static str {
                "dead"
            }
            fn plan(&mut self, _batch: usize) -> Vec<PointConfig> {
                Vec::new()
            }
            fn observe(&mut self, _results: &[(PointConfig, MeasureResult)]) {}
        }
        let s = space();
        let r = tune_task(&s, &mut Dead, TuneBudget::default()).unwrap();
        assert_eq!(r.measurements, 0);
        assert!(r.best_point.is_none());
    }

    #[test]
    fn shared_engine_dedups_across_runs() {
        let s = space();
        let engine = crate::eval::Engine::vta_sim(2);
        let budget =
            TuneBudget { total_measurements: 48, batch: 16, workers: 2, ..Default::default() };
        let run = |engine: &crate::eval::Engine| {
            let mut strat = RandomProbe {
                space: s.clone(),
                rng: Pcg32::seeded(4),
                seen: HashSet::new(),
                observed: 0,
            };
            tune_task_with(engine, &s, &mut strat, budget).unwrap()
        };
        let a = run(&engine);
        let sims_after_first = engine.stats().simulations;
        assert_eq!(sims_after_first, 48);
        let b = run(&engine);
        assert_eq!(a.best.seconds, b.best.seconds);
        // Same seed → same plan → the second run is fully cache-served.
        assert_eq!(engine.stats().simulations, sims_after_first);
        assert!(engine.stats().cache_hits >= 48);
    }

    /// A strategy that ignores the requested batch size and plans three
    /// times as many points — the over-planning bug's trigger.
    struct OverPlanner {
        inner: RandomProbe,
    }

    impl Strategy for OverPlanner {
        fn name(&self) -> &'static str {
            "overplanner"
        }
        fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
            self.inner.plan(batch * 3)
        }
        fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
            self.inner.observe(results);
        }
    }

    #[test]
    fn over_planning_strategy_cannot_breach_the_budget() {
        let s = space();
        let mut strat = OverPlanner {
            inner: RandomProbe {
                space: s.clone(),
                rng: Pcg32::seeded(6),
                seen: HashSet::new(),
                observed: 0,
            },
        };
        let budget =
            TuneBudget { total_measurements: 40, batch: 16, workers: 2, ..Default::default() };
        let r = tune_task(&s, &mut strat, budget).unwrap();
        assert_eq!(r.measurements, 40, "plan truncation must land exactly on the budget");
        assert_eq!(r.trace.len(), 40);
        assert_eq!(r.trace.last().unwrap().ordinal, 40);
        // The strategy only observes what was actually measured.
        assert_eq!(strat.inner.observed, 40);
    }

    #[test]
    fn degenerate_parity_target_is_never_reached() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(8),
            seen: HashSet::new(),
            observed: 0,
        };
        let budget =
            TuneBudget { total_measurements: 16, batch: 8, workers: 2, ..Default::default() };
        let r = tune_task(&s, &mut strat, budget).unwrap();
        assert!(r.modeled_hw_secs > 0.0);
        // A zero/negative/NaN target (missing or empty baseline) charges
        // the full modeled time instead of "parity at the first entry".
        assert_eq!(r.modeled_secs_to_quality(0.0), r.modeled_hw_secs);
        assert_eq!(r.modeled_secs_to_quality(-1.0), r.modeled_hw_secs);
        assert_eq!(r.modeled_secs_to_quality(f64::NAN), r.modeled_hw_secs);
        // A real (positive) target is still reachable mid-trace.
        let reached = r.trace.last().unwrap().best_gflops;
        if reached > 0.0 {
            assert!(r.modeled_secs_to_quality(reached * 0.5) <= r.modeled_hw_secs);
        }
    }

    #[test]
    fn provenance_counts_cover_every_measurement() {
        let s = space();
        let engine = crate::eval::Engine::vta_sim(2);
        let budget =
            TuneBudget { total_measurements: 32, batch: 16, workers: 2, ..Default::default() };
        let run = |engine: &crate::eval::Engine, seed: u64| {
            let mut strat = RandomProbe {
                space: s.clone(),
                rng: Pcg32::seeded(seed),
                seen: HashSet::new(),
                observed: 0,
            };
            tune_task_with(engine, &s, &mut strat, budget).unwrap()
        };
        let a = run(&engine, 12);
        assert_eq!(a.fresh + a.cache_served, a.measurements);
        assert_eq!(a.fresh, a.measurements, "first run on a cold cache is all fresh");
        // The identical run replays from the cache: same debit, no
        // simulator time — the "measure once, charge everyone" split.
        let b = run(&engine, 12);
        assert_eq!(b.measurements, a.measurements);
        assert_eq!(b.fresh, 0);
        assert_eq!(b.cache_served, b.measurements);
    }

    #[test]
    fn timer_tracks_phases() {
        let s = space();
        let mut strat = RandomProbe {
            space: s.clone(),
            rng: Pcg32::seeded(3),
            seen: HashSet::new(),
            observed: 0,
        };
        let r = tune_task(&s, &mut strat, TuneBudget { total_measurements: 32, batch: 16, workers: 1, ..Default::default() }).unwrap();
        assert!(r.timer.count("plan") >= 2);
        assert!(r.timer.count("measure") >= 2);
        assert!(r.timer.count("observe") >= 2);
    }
}
