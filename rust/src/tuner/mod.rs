//! Tuning orchestration: the iterate → plan → measure → learn loop shared
//! by every framework (Fig. 2's outer cycle), per-task and per-model
//! drivers, and the comparison harness behind Figs. 5–7 / Table 6.

pub mod compare;
pub mod strategy;
pub mod task_tuner;

pub use compare::{
    compare_frameworks, compare_frameworks_opts, compare_frameworks_with, tune_model,
    tune_model_concurrent, tune_model_with, CompareReport, DriverOptions, Framework,
    ModelOutcome, SharedRun, TaskOutcome,
};
pub use strategy::Strategy;
pub use task_tuner::{
    tune_task, tune_task_tenant, tune_task_with, Fidelity, TaskTuneResult, TenantContext,
    TraceEntry, TraceFidelity, TuneBudget, TuneObserver, DEFAULT_EXPLORE_FRAC, SCREEN_COST_SECS,
};
