//! AutoTVM baseline: GBT cost model + parallel simulated annealing.
//!
//! Mirrors Chen et al. (OSDI'18) as configured in Table 5: a gradient-
//! boosted-tree regressor (`xgb-reg`) is refit on all measured
//! (features → fitness) pairs each iteration; `n_sa` simulated-annealing
//! chains of `step_sa` steps walk the knob space maximizing the predicted
//! score; the top-`b` distinct unmeasured visits become the next
//! measurement batch. Before the model has data, planning is uniform.

use super::kmeans; // only for the greedy-diversity helper reuse
use crate::eval::MeasureResult;
use crate::costmodel::{featurize, CostModel, Gbt, GbtParams};
use crate::space::{ConfigSpace, PointConfig};
use crate::tuner::Strategy;
use crate::util::rng::Pcg32;
use std::collections::{HashMap, HashSet};

/// Table 5 knobs.
#[derive(Debug, Clone, Copy)]
pub struct AutoTvmParams {
    /// Parallel Markov chains in the SA planner.
    pub n_sa: usize,
    /// Steps per SA chain.
    pub step_sa: usize,
    /// SA temperature schedule (start, end).
    pub temp: (f64, f64),
    /// ε-greedy fraction of the batch planned uniformly at random.
    pub eps_random: f64,
    /// GBT settings.
    pub gbt: GbtParams,
}

impl Default for AutoTvmParams {
    fn default() -> Self {
        AutoTvmParams {
            n_sa: 128,
            step_sa: 500,
            temp: (1.0, 0.0),
            eps_random: 0.05,
            gbt: GbtParams::default(),
        }
    }
}

/// Scaled-down SA budget for CI-speed runs (same structure).
impl AutoTvmParams {
    pub fn quick() -> AutoTvmParams {
        AutoTvmParams { n_sa: 32, step_sa: 60, ..Default::default() }
    }
}

/// The AutoTVM strategy.
pub struct AutoTvm {
    space: ConfigSpace,
    params: AutoTvmParams,
    rng: Pcg32,
    model: Gbt,
    /// Measured data: features + fitness.
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    seen: HashSet<usize>,
}

impl AutoTvm {
    /// `space` should have hardware knobs frozen (the paper runs AutoTVM
    /// on the default VTA++ spec).
    pub fn new(space: ConfigSpace, params: AutoTvmParams, seed: u64) -> AutoTvm {
        let gbt = Gbt::new(params.gbt);
        AutoTvm {
            space,
            params,
            rng: Pcg32::seeded(seed),
            model: gbt,
            xs: Vec::new(),
            ys: Vec::new(),
            seen: HashSet::new(),
        }
    }

    /// Run the parallel-SA planner; returns candidate points with
    /// predicted scores, best-per-chain visits included.
    fn simulated_annealing(&mut self) -> Vec<(PointConfig, f64)> {
        let p = self.params;
        let mut results: HashMap<usize, (PointConfig, f64)> = HashMap::new();
        for _chain in 0..p.n_sa {
            let mut cur = self.space.random_point(&mut self.rng);
            let mut cur_score = self.predict(&cur);
            for step in 0..p.step_sa {
                let frac = step as f64 / p.step_sa.max(1) as f64;
                let temp = p.temp.0 + (p.temp.1 - p.temp.0) * frac;
                let neighbours = self.space.neighbours(&cur);
                if neighbours.is_empty() {
                    break;
                }
                let next = neighbours[self.rng.gen_range(neighbours.len())].clone();
                let next_score = self.predict(&next);
                let accept = next_score > cur_score
                    || (temp > 0.0
                        && self.rng.gen_bool(((next_score - cur_score) / temp).exp().min(1.0)));
                if accept {
                    cur = next;
                    cur_score = next_score;
                }
                let key = self.space.flat_index(&cur);
                if !self.seen.contains(&key) {
                    let entry = results.entry(key).or_insert_with(|| (cur.clone(), cur_score));
                    entry.1 = cur_score;
                }
            }
        }
        // Deterministic order: score descending, flat index breaking ties.
        // HashMap iteration order varies per process, and the remote
        // measurement smoke (`scripts/ci_smoke_remote.sh`) asserts that two
        // processes plan identically from identical observations.
        let mut v: Vec<(usize, (PointConfig, f64))> = results.into_iter().collect();
        v.sort_by(|a, b| {
            b.1 .1
                .partial_cmp(&a.1 .1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then_with(|| a.0.cmp(&b.0))
        });
        v.into_iter().map(|(_, pv)| pv).collect()
    }

    fn predict(&self, p: &PointConfig) -> f64 {
        if self.model.is_trained() {
            self.model.predict(&featurize(&self.space, p))
        } else {
            0.0
        }
    }

    fn random_unseen(&mut self, n: usize) -> Vec<PointConfig> {
        let mut out = Vec::new();
        let mut attempts = 0;
        while out.len() < n && attempts < n * 100 {
            let p = self.space.random_point(&mut self.rng);
            if self.seen.insert(self.space.flat_index(&p)) {
                out.push(p);
            }
            attempts += 1;
        }
        out
    }
}

impl Strategy for AutoTvm {
    fn name(&self) -> &'static str {
        "autotvm"
    }

    fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
        if !self.model.is_trained() {
            // Cold start: uniform sampling (AutoTVM's first batch).
            return self.random_unseen(batch);
        }
        let n_random = ((batch as f64) * self.params.eps_random).ceil() as usize;
        let n_model = batch.saturating_sub(n_random);

        let candidates = self.simulated_annealing();
        let mut out: Vec<PointConfig> = Vec::with_capacity(batch);
        // Greedy-diverse top-k: take best-scored candidates but skip ones
        // identical in feature space to an already-picked candidate.
        let mut picked_feats: Vec<Vec<f64>> = Vec::new();
        for (p, _score) in candidates {
            if out.len() >= n_model {
                break;
            }
            let f = featurize(&self.space, &p);
            if picked_feats.iter().any(|g| kmeans::sq_dist(g, &f) < 1e-12) {
                continue;
            }
            self.seen.insert(self.space.flat_index(&p));
            picked_feats.push(f);
            out.push(p);
        }
        out.extend(self.random_unseen(batch - out.len().min(batch)));
        out.truncate(batch);
        out
    }

    fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
        for (p, r) in results {
            self.seen.insert(self.space.flat_index(p));
            self.xs.push(featurize(&self.space, p));
            // Regress on fitness (1/sec); invalid = 0, exactly the signal
            // AutoTVM feeds xgboost.
            self.ys.push(r.fitness());
        }
        self.model.fit(&self.xs, &self.ys);
    }

    /// Safe at any pipeline depth: `seen` is updated at plan time (an
    /// in-flight point is never re-proposed), and a GBT refit that lands a
    /// batch late only means one SA round runs on a slightly stale
    /// surrogate — the regressor is refit from the *full* history on every
    /// observe, so no data is lost, it is just consulted later.
    fn max_pipeline_depth(&self) -> usize {
        usize::MAX
    }

    fn diag(&self) -> String {
        format!("gbt_trees={} data={}", self.model.num_trees(), self.ys.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Engine;
    use crate::tuner::{tune_task, TuneBudget};
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 28, 28, 64, 3, 3, 1, 1), false)
    }

    #[test]
    fn cold_start_plans_random() {
        let s = space();
        let mut a = AutoTvm::new(s.clone(), AutoTvmParams::quick(), 1);
        let plan = a.plan(16);
        assert_eq!(plan.len(), 16);
        let keys: HashSet<usize> = plan.iter().map(|p| s.flat_index(p)).collect();
        assert_eq!(keys.len(), 16);
    }

    #[test]
    fn model_trains_after_observe() {
        let s = space();
        let engine = Engine::vta_sim(2);
        let mut a = AutoTvm::new(s.clone(), AutoTvmParams::quick(), 2);
        let plan = a.plan(32);
        let results: Vec<(PointConfig, MeasureResult)> = engine.measure_paired(&s, plan).pairs;
        a.observe(&results);
        assert!(a.model.is_trained());
        assert!(a.diag().contains("data=32"));
    }

    #[test]
    fn never_replans_measured_configs() {
        let s = space();
        let engine = Engine::vta_sim(2);
        let mut a = AutoTvm::new(s.clone(), AutoTvmParams::quick(), 3);
        let mut all_keys = HashSet::new();
        for _ in 0..4 {
            let plan = a.plan(24);
            for p in &plan {
                assert!(all_keys.insert(s.flat_index(p)), "config planned twice");
            }
            a.observe(&engine.measure_paired(&s, plan).pairs);
        }
        // Nothing was planned twice, so the engine paid for every point.
        assert_eq!(engine.stats().simulations, all_keys.len());
    }

    #[test]
    fn beats_random_search_on_budget() {
        // The cost model should focus measurements: with the same budget,
        // AutoTVM's best config should be at least as good as random's.
        let s = space();
        let budget = TuneBudget { total_measurements: 192, batch: 32, workers: 2, ..Default::default() };
        let mut atvm = AutoTvm::new(s.clone(), AutoTvmParams::quick(), 7);
        let r_atvm = tune_task(&s, &mut atvm, budget).unwrap();
        let mut rnd = crate::baselines::RandomSearch::new(s.clone(), 7);
        let r_rnd = tune_task(&s, &mut rnd, budget).unwrap();
        assert!(
            r_atvm.best.gflops >= r_rnd.best.gflops * 0.95,
            "autotvm {} vs random {}",
            r_atvm.best.gflops,
            r_rnd.best.gflops
        );
    }
}
