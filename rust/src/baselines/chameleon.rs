//! CHAMELEON baseline (Ahn et al., ICLR'20): Adaptive Exploration +
//! Adaptive Sampling.
//!
//! - **Adaptive Exploration**: a single-agent PPO policy walks the
//!   (software) knob space against the GBT cost model's predicted fitness,
//!   replacing AutoTVM's simulated annealing. One action = step one knob
//!   up/down (or stay), so the action space is `2*num_knobs + 1`.
//! - **Adaptive Sampling**: the explored candidates are clustered with
//!   k-means in feature space and one exemplar per cluster is measured,
//!   cutting costly hardware measurements.
//!
//! Runs entirely on the native ML substrate (its networks are CHAMELEON's,
//! not the paper's MAPPO graphs, so they are not part of the AOT bundle).

use super::kmeans::{exemplars, kmeans};
use crate::marl::env::memory_overflow_ratio;
use crate::eval::MeasureResult;
use crate::costmodel::{featurize, CostModel, Gbt, GbtParams};
use crate::ml::{clip_grad_norm, ppo, Adam, AdamParams, Mat, Mlp};
use crate::space::{ConfigSpace, PointConfig};
use crate::tuner::Strategy;
use crate::util::rng::Pcg32;
use std::collections::{HashMap, HashSet};

/// CHAMELEON hyper-parameters (Table 4's RL column: episodes/steps mirror
/// the ARCO round budget; defaults scaled as in `ExploreParams`).
#[derive(Debug, Clone, Copy)]
pub struct ChameleonParams {
    pub episodes: usize,
    pub steps: usize,
    pub population: usize,
    pub ppo_epochs: usize,
    pub gamma: f32,
    pub lam: f32,
    pub clip_eps: f32,
    pub entropy_coef: f32,
    pub lr: f32,
    pub gbt: GbtParams,
}

impl Default for ChameleonParams {
    fn default() -> Self {
        ChameleonParams {
            episodes: 8,
            steps: 24,
            population: 32,
            ppo_epochs: 2,
            gamma: 0.99,
            lam: 0.95,
            clip_eps: 0.2,
            entropy_coef: 0.01,
            lr: 5e-3,
            gbt: GbtParams::default(),
        }
    }
}

impl ChameleonParams {
    pub fn quick() -> ChameleonParams {
        ChameleonParams { episodes: 3, steps: 10, population: 16, ..Default::default() }
    }
}

const OBS: usize = 12;

/// The CHAMELEON strategy.
pub struct Chameleon {
    space: ConfigSpace,
    params: ChameleonParams,
    rng: Pcg32,
    policy: Mlp,
    policy_opt: Adam,
    value: Mlp,
    value_opt: Adam,
    model: Gbt,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    seen: HashSet<usize>,
    n_actions: usize,
    mask: Vec<f32>,
    best_fitness: f64,
}

impl Chameleon {
    pub fn new(space: ConfigSpace, params: ChameleonParams, seed: u64) -> Chameleon {
        let mut rng = Pcg32::seeded(seed);
        let n_actions = 2 * space.num_knobs() + 1;
        let policy = Mlp::policy(OBS, n_actions, &mut rng);
        let value = Mlp::new(
            &[OBS, 20, 1],
            &[crate::ml::Act::Tanh, crate::ml::Act::Linear],
            &mut rng,
        );
        let policy_opt = Adam::new(policy.num_params(), AdamParams { lr: params.lr, ..Default::default() });
        let value_opt = Adam::new(value.num_params(), AdamParams { lr: params.lr, ..Default::default() });
        Chameleon {
            space,
            params,
            rng,
            policy,
            policy_opt,
            value,
            value_opt,
            model: Gbt::new(params.gbt),
            xs: Vec::new(),
            ys: Vec::new(),
            seen: HashSet::new(),
            n_actions,
            mask: vec![1.0; n_actions],
            best_fitness: 0.0,
        }
    }

    fn observe_point(&self, p: &PointConfig, last_reward: f32, step_frac: f32) -> Vec<f32> {
        let mut o: Vec<f32> =
            self.space.normalized(p).into_iter().map(|x| x as f32).collect();
        o.push(last_reward.clamp(-4.0, 4.0));
        o.push(step_frac);
        o.resize(OBS, 0.0);
        o
    }

    /// Action k=0: stay; k=1..: knob (k-1)/2 stepped (-1 if odd, +1 if even).
    fn apply(&self, p: &PointConfig, action: usize) -> PointConfig {
        if action == 0 {
            return p.clone();
        }
        let knob = (action - 1) / 2;
        let delta: i64 = if action % 2 == 1 { -1 } else { 1 };
        let mut q = p.clone();
        if self.space.knob_frozen(knob) {
            return q;
        }
        let arity = self.space.knobs[knob].len() as i64;
        q.0[knob] = ((q.0[knob] as i64 + delta).clamp(0, arity - 1)) as usize;
        q
    }

    fn predict(&self, p: &PointConfig) -> f64 {
        if self.model.is_trained() {
            self.model.predict(&featurize(&self.space, p))
        } else {
            0.0
        }
    }

    /// Adaptive Exploration: PPO rollouts over the surrogate landscape.
    /// Returns distinct visited candidates with predicted scores.
    fn adaptive_exploration(&mut self) -> Vec<(PointConfig, f64)> {
        let pr = self.params;
        let mut visited: HashMap<usize, (PointConfig, f64)> = HashMap::new();
        let norm = self.best_fitness.max(1e-12);

        for _ep in 0..pr.episodes {
            let mut pop: Vec<PointConfig> =
                (0..pr.population).map(|_| self.space.random_point(&mut self.rng)).collect();
            let mut last_r = vec![0.0f32; pr.population];
            // Rollout buffers.
            let mut obs_buf: Vec<Vec<f32>> = Vec::new();
            let mut act_buf: Vec<usize> = Vec::new();
            let mut logp_buf: Vec<f32> = Vec::new();
            let mut rew_buf: Vec<Vec<f32>> = vec![Vec::new(); pr.population];
            let mut val_buf: Vec<Vec<f32>> = vec![Vec::new(); pr.population];

            for step in 0..pr.steps {
                let frac = step as f32 / pr.steps.max(1) as f32;
                let obs_rows: Vec<Vec<f32>> = pop
                    .iter()
                    .zip(&last_r)
                    .map(|(p, &lr)| self.observe_point(p, lr, frac))
                    .collect();
                let obs_mat = Mat::from_vec(
                    pr.population,
                    OBS,
                    obs_rows.iter().flatten().cloned().collect(),
                );
                let cache = self.policy.forward(&obs_mat);
                let logp = ppo::masked_log_softmax(cache.output(), &self.mask);
                let vals = self.value.forward(&obs_mat).output().data.clone();
                for i in 0..pr.population {
                    let probs: Vec<f64> = (0..self.n_actions)
                        .map(|a| (logp.at(i, a) as f64).exp())
                        .collect();
                    let action = self.rng.gen_weighted(&probs);
                    let next = self.apply(&pop[i], action);
                    let score = self.predict(&next);
                    let reward = (score / norm) as f32;
                    obs_buf.push(obs_rows[i].clone());
                    act_buf.push(action);
                    logp_buf.push(logp.at(i, action));
                    rew_buf[i].push(reward);
                    val_buf[i].push(vals[i]);
                    last_r[i] = reward;
                    let key = self.space.flat_index(&next);
                    if !self.seen.contains(&key) {
                        visited.insert(key, (next.clone(), score));
                    }
                    pop[i] = next;
                }
            }

            // GAE per trajectory, interleaved layout: index = step*pop + i.
            let mut adv_buf = vec![0.0f32; obs_buf.len()];
            let mut ret_buf = vec![0.0f32; obs_buf.len()];
            for i in 0..pr.population {
                let (adv, ret) =
                    ppo::gae(&rew_buf[i], &val_buf[i], 0.0, pr.gamma, pr.lam);
                for (s, (&a, &r)) in adv.iter().zip(&ret).enumerate() {
                    adv_buf[s * pr.population + i] = a;
                    ret_buf[s * pr.population + i] = r;
                }
            }
            ppo::normalize_advantages(&mut adv_buf);

            // PPO updates.
            for _ in 0..pr.ppo_epochs {
                let n = obs_buf.len();
                let obs_mat =
                    Mat::from_vec(n, OBS, obs_buf.iter().flatten().cloned().collect());
                let cache = self.policy.forward(&obs_mat);
                let (_, d_logits, _, _) = ppo::ppo_policy_loss_grad(
                    cache.output(),
                    &self.mask,
                    &act_buf,
                    &logp_buf,
                    &adv_buf,
                    pr.clip_eps,
                    pr.entropy_coef,
                );
                let grads = self.policy.backward(&cache, &d_logits);
                let mut flat = Mlp::flatten_grads(&grads);
                clip_grad_norm(&mut flat, 10.0);
                let mut theta = self.policy.flatten();
                self.policy_opt.step(&mut theta, &flat);
                self.policy.unflatten(&theta);

                let vcache = self.value.forward(&obs_mat);
                let (_, d_out) = ppo::value_loss_grad(vcache.output(), &ret_buf);
                let vgrads = self.value.backward(&vcache, &d_out);
                let mut vflat = Mlp::flatten_grads(&vgrads);
                clip_grad_norm(&mut vflat, 10.0);
                let mut vtheta = self.value.flatten();
                self.value_opt.step(&mut vtheta, &vflat);
                self.value.unflatten(&vtheta);
            }
        }
        // Deterministic order (flat index): HashMap iteration varies per
        // process, and the clustering downstream is order-sensitive — two
        // processes must plan identically from identical observations.
        let mut v: Vec<(usize, (PointConfig, f64))> = visited.into_iter().collect();
        v.sort_by_key(|&(k, _)| k);
        v.into_iter().map(|(_, pv)| pv).collect()
    }

    /// Random unmeasured configurations, filtered by the scratchpad
    /// constraint check — CHAMELEON's stated goal of "minimizing invalid
    /// configurations and costly hardware measurements".
    fn random_unseen(&mut self, n: usize) -> Vec<PointConfig> {
        let mut out = Vec::new();
        let mut attempts = 0;
        while out.len() < n && attempts < n * 200 {
            let p = self.space.random_point(&mut self.rng);
            attempts += 1;
            if memory_overflow_ratio(&self.space, &p) > 0.0 {
                continue;
            }
            if self.seen.insert(self.space.flat_index(&p)) {
                out.push(p);
            }
        }
        let mut fallback = 0;
        while out.is_empty() && fallback < n * 100 {
            let p = self.space.random_point(&mut self.rng);
            fallback += 1;
            if self.seen.insert(self.space.flat_index(&p)) {
                out.push(p);
            }
        }
        out
    }
}

impl Strategy for Chameleon {
    fn name(&self) -> &'static str {
        "chameleon"
    }

    fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
        if !self.model.is_trained() {
            return self.random_unseen(batch);
        }
        let candidates = self.adaptive_exploration();
        if candidates.is_empty() {
            return self.random_unseen(batch);
        }
        // Adaptive Sampling: cluster candidates, measure exemplars.
        let feats: Vec<Vec<f64>> =
            candidates.iter().map(|(p, _)| featurize(&self.space, p)).collect();
        let km = kmeans(&feats, batch, 12, &mut self.rng);
        let ex = exemplars(&feats, &km);
        let mut out = Vec::with_capacity(batch);
        for i in ex {
            let p = candidates[i].0.clone();
            if memory_overflow_ratio(&self.space, &p) > 0.0 {
                continue; // invalid-config filter (Adaptive Sampling)
            }
            if self.seen.insert(self.space.flat_index(&p)) {
                out.push(p);
            }
        }
        // No random backfill: Adaptive Sampling's point is to measure
        // exemplars only, trading batch fill for fewer hardware runs.
        if out.is_empty() {
            return self.random_unseen(batch.min(8));
        }
        out.truncate(batch);
        out
    }

    fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
        for (p, r) in results {
            self.seen.insert(self.space.flat_index(p));
            self.xs.push(featurize(&self.space, p));
            self.ys.push(r.fitness());
            if r.fitness() > self.best_fitness {
                self.best_fitness = r.fitness();
            }
        }
        self.model.fit(&self.xs, &self.ys);
    }

    /// Safe at any pipeline depth: `seen` is updated at plan time, so
    /// Adaptive Exploration never revisits an in-flight candidate, and a
    /// late surrogate refit (the GBT is rebuilt from the full history each
    /// observe) only staleness-shifts one PPO round's reward landscape.
    fn max_pipeline_depth(&self) -> usize {
        usize::MAX
    }

    fn diag(&self) -> String {
        format!(
            "gbt_trees={} data={} best_fit={:.3e}",
            self.model.num_trees(),
            self.ys.len(),
            self.best_fitness
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Engine;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 28, 28, 64, 3, 3, 1, 1), false)
    }

    #[test]
    fn apply_action_semantics() {
        let s = space();
        let c = Chameleon::new(s.clone(), ChameleonParams::quick(), 1);
        let p = s.default_point();
        assert_eq!(c.apply(&p, 0), p); // stay
        // Action 2 = knob 0 incremented, but knob 0 is a frozen hw knob.
        assert_eq!(c.apply(&p, 2), p);
        // A mapping knob (tile_h = knob 5): action 1 + 2*5 + 1 = 12 (inc).
        let k = s.knob_index("tile_h").unwrap();
        let inc_action = 2 + 2 * k;
        let q = c.apply(&p, inc_action);
        assert_eq!(q.0[k], p.0[k] + 1);
    }

    #[test]
    fn full_tuning_round_trip() {
        let s = space();
        let engine = Engine::vta_sim(2);
        let mut c = Chameleon::new(s.clone(), ChameleonParams::quick(), 2);
        // Cold batch.
        let plan = c.plan(16);
        assert_eq!(plan.len(), 16);
        c.observe(&engine.measure_paired(&s, plan).pairs);
        assert!(c.model.is_trained());
        // Warm batch uses RL + clustering.
        let plan2 = c.plan(16);
        assert!(!plan2.is_empty());
        let keys: HashSet<usize> = plan2.iter().map(|p| s.flat_index(p)).collect();
        assert_eq!(keys.len(), plan2.len());
    }

    #[test]
    fn policy_trains_during_exploration() {
        let s = space();
        let engine = Engine::vta_sim(2);
        let mut c = Chameleon::new(s.clone(), ChameleonParams::quick(), 3);
        // Seed the model so exploration runs.
        let plan = c.plan(16);
        c.observe(&engine.measure_paired(&s, plan).pairs);
        let before = c.policy.flatten();
        let _ = c.adaptive_exploration();
        assert_ne!(c.policy.flatten(), before, "PPO updates must move the policy");
    }

    #[test]
    fn respects_frozen_hardware() {
        let s = space();
        let engine = Engine::vta_sim(2);
        let mut c = Chameleon::new(s.clone(), ChameleonParams::quick(), 4);
        for _round in 0..2 {
            let plan = c.plan(12);
            for p in &plan {
                let (hw, _) = s.decode(p);
                assert_eq!((hw.batch, hw.block_in, hw.block_out), (1, 16, 16));
            }
            c.observe(&engine.measure_paired(&s, plan).pairs);
        }
    }
}
