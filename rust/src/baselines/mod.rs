//! Baseline frameworks the paper compares against (§4):
//!
//! - [`random`] — uniform random search (sanity floor, not in the paper's
//!   figures but used by the ablation benches);
//! - [`autotvm`] — AutoTVM: XGBoost-style GBT cost model + parallel
//!   simulated annealing planner + uniform candidate sampling (Table 5);
//! - [`chameleon`] — CHAMELEON: single-agent RL adaptive exploration +
//!   k-means adaptive sampling.
//!
//! Both baselines run with the hardware knobs frozen at the VTA++ default,
//! exactly as §4.1 prescribes ("AutoTVM and CHAMELEON do not support
//! hardware configuration exploration").

pub mod autotvm;
pub mod chameleon;
pub mod kmeans;
pub mod random;

pub use autotvm::AutoTvm;
pub use chameleon::Chameleon;
pub use random::RandomSearch;
