//! Uniform random search — the sanity-floor baseline.

use crate::eval::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::tuner::Strategy;
use crate::util::rng::Pcg32;
use std::collections::HashSet;

/// Plans uniform-random unmeasured configurations.
pub struct RandomSearch {
    space: ConfigSpace,
    rng: Pcg32,
    seen: HashSet<usize>,
}

impl RandomSearch {
    pub fn new(space: ConfigSpace, seed: u64) -> RandomSearch {
        RandomSearch { space, rng: Pcg32::seeded(seed), seen: HashSet::new() }
    }
}

impl Strategy for RandomSearch {
    fn name(&self) -> &'static str {
        "random"
    }

    fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
        let mut out = Vec::with_capacity(batch);
        let space_size = self.space.size();
        let mut attempts = 0usize;
        while out.len() < batch && attempts < batch * 100 && self.seen.len() < space_size {
            let p = self.space.random_point(&mut self.rng);
            if self.seen.insert(self.space.flat_index(&p)) {
                out.push(p);
            }
            attempts += 1;
        }
        out
    }

    fn observe(&mut self, _results: &[(PointConfig, MeasureResult)]) {}

    /// Uniform sampling never consults results at all, and `seen` is
    /// updated at plan time, so any pipeline depth is safe: plans are
    /// identical whether observations arrive promptly or batches late.
    fn max_pipeline_depth(&self) -> usize {
        usize::MAX
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Conv2dTask;

    #[test]
    fn plans_distinct_configs() {
        let s = ConfigSpace::for_task(&Conv2dTask::new(1, 32, 14, 14, 32, 3, 3, 1, 1), false);
        let mut r = RandomSearch::new(s.clone(), 3);
        let a = r.plan(32);
        let b = r.plan(32);
        let mut keys = HashSet::new();
        for p in a.iter().chain(&b) {
            assert!(keys.insert(s.flat_index(p)), "duplicate plan");
        }
    }

    #[test]
    fn exhausts_small_space_gracefully() {
        let s = ConfigSpace::for_task(&Conv2dTask::new(1, 8, 4, 4, 8, 3, 3, 1, 1), false);
        let size = s.size();
        let mut r = RandomSearch::new(s, 1);
        let mut total = 0;
        for _ in 0..50 {
            total += r.plan(64).len();
        }
        assert!(total <= size);
        assert!(total >= size / 2, "should cover most of a small space");
    }
}
