//! Lloyd's k-means over feature vectors — the clustering engine behind
//! CHAMELEON's Adaptive Sampling (cluster the candidate configurations,
//! measure one exemplar per cluster).

use crate::util::rng::Pcg32;

/// Squared Euclidean distance.
pub fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k-means result: assignment per point and centroids.
#[derive(Debug, Clone)]
pub struct KMeans {
    pub assignment: Vec<usize>,
    pub centroids: Vec<Vec<f64>>,
}

/// Cluster `points` into `k` groups (k-means++ init, Lloyd iterations).
pub fn kmeans(points: &[Vec<f64>], k: usize, iters: usize, rng: &mut Pcg32) -> KMeans {
    assert!(!points.is_empty());
    let k = k.min(points.len()).max(1);
    let dim = points[0].len();

    // k-means++ seeding.
    let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
    centroids.push(points[rng.gen_range(points.len())].clone());
    while centroids.len() < k {
        let d2: Vec<f64> = points
            .iter()
            .map(|p| {
                centroids
                    .iter()
                    .map(|c| sq_dist(p, c))
                    .fold(f64::INFINITY, f64::min)
            })
            .collect();
        let idx = rng.gen_weighted(&d2);
        centroids.push(points[idx].clone());
    }

    let mut assignment = vec![0usize; points.len()];
    for _ in 0..iters {
        // Assign.
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for (c, centroid) in centroids.iter().enumerate() {
                let d = sq_dist(p, centroid);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // Update.
        let mut sums = vec![vec![0.0f64; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            let c = assignment[i];
            counts[c] += 1;
            for (s, x) in sums[c].iter_mut().zip(p) {
                *s += x;
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for s in sums[c].iter_mut() {
                    *s /= counts[c] as f64;
                }
                centroids[c] = sums[c].clone();
            } else {
                // Re-seed empty clusters at a random point.
                centroids[c] = points[rng.gen_range(points.len())].clone();
            }
        }
        if !changed {
            break;
        }
    }
    KMeans { assignment, centroids }
}

/// Index of the point nearest each centroid (cluster exemplars).
pub fn exemplars(points: &[Vec<f64>], km: &KMeans) -> Vec<usize> {
    let k = km.centroids.len();
    let mut best = vec![usize::MAX; k];
    let mut best_d = vec![f64::INFINITY; k];
    for (i, p) in points.iter().enumerate() {
        let c = km.assignment[i];
        let d = sq_dist(p, &km.centroids[c]);
        if d < best_d[c] {
            best_d[c] = d;
            best[c] = i;
        }
    }
    best.into_iter().filter(|&i| i != usize::MAX).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(center: f64, n: usize, rng: &mut Pcg32) -> Vec<Vec<f64>> {
        (0..n).map(|_| vec![center + 0.1 * rng.gen_f64(), center - 0.1 * rng.gen_f64()]).collect()
    }

    #[test]
    fn separates_two_blobs() {
        let mut rng = Pcg32::seeded(4);
        let mut pts = blob(0.0, 30, &mut rng);
        pts.extend(blob(10.0, 30, &mut rng));
        let km = kmeans(&pts, 2, 20, &mut rng);
        // All points in one blob share an assignment.
        let a0 = km.assignment[0];
        assert!(km.assignment[..30].iter().all(|&a| a == a0));
        let a1 = km.assignment[30];
        assert!(km.assignment[30..].iter().all(|&a| a == a1));
        assert_ne!(a0, a1);
    }

    #[test]
    fn exemplars_one_per_cluster() {
        let mut rng = Pcg32::seeded(5);
        let mut pts = blob(0.0, 20, &mut rng);
        pts.extend(blob(5.0, 20, &mut rng));
        pts.extend(blob(10.0, 20, &mut rng));
        let km = kmeans(&pts, 3, 20, &mut rng);
        let ex = exemplars(&pts, &km);
        assert_eq!(ex.len(), 3);
        let set: std::collections::HashSet<usize> = ex.iter().cloned().collect();
        assert_eq!(set.len(), 3);
    }

    #[test]
    fn k_larger_than_points_is_clamped() {
        let mut rng = Pcg32::seeded(6);
        let pts = blob(1.0, 3, &mut rng);
        let km = kmeans(&pts, 10, 5, &mut rng);
        assert_eq!(km.centroids.len(), 3);
    }

    #[test]
    fn single_cluster_centroid_is_mean() {
        let pts = vec![vec![0.0, 0.0], vec![2.0, 2.0]];
        let mut rng = Pcg32::seeded(7);
        let km = kmeans(&pts, 1, 10, &mut rng);
        assert!((km.centroids[0][0] - 1.0).abs() < 1e-9);
        assert!((km.centroids[0][1] - 1.0).abs() < 1e-9);
    }
}
