//! `arco devcheck` — a domain-specific static-analysis pass over this
//! repository's own sources. Generic lints (clippy) cannot see the
//! eval-layer contracts; this pass enforces them mechanically:
//!
//! - [`panic_free`]: no reachable panic in the daemon/wire modules —
//!   one bad peer must not take down the process.
//! - [`ledger_order`]: `charge(...)` lexically precedes every engine
//!   batch submission; `settle(...)` never does.
//! - [`codec`]: the tree parser (`Json::parse`) stays out of the codec
//!   hot paths, confined to named lenient-fallback functions.
//! - [`guard_io`]: no live `MutexGuard` spans a socket write.
//! - [`wire_docs`]: docs/WIRE.md and docs/OPERATIONS.md track the wire
//!   protocol — field names and error texts — in both directions.
//!
//! The pass works on a token stream from a small purpose-built Rust
//! lexer ([`lexer`]) — enough structure to be precise about strings,
//! comments and `#[cfg(test)]` regions without dragging in a full
//! parser. Findings anchor to `file:line` and can be waived, one line
//! at a time, with `// devcheck:allow(<rule>)` on the finding's line or
//! the line above. Run as `arco devcheck` (exit 1 on findings); CI runs
//! it alongside clippy.

pub mod codec;
pub mod guard_io;
pub mod ledger_order;
pub mod lexer;
pub mod model;
pub mod panic_free;
pub mod wire_docs;

use model::SourceFile;
use std::fs;
use std::path::{Path, PathBuf};

/// One rule violation, anchored to a repo-relative file and line.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn render(&self) -> String {
        format!(
            "devcheck: {}: {}:{}: {}",
            self.rule, self.file, self.line, self.message
        )
    }
}

/// Every rule name, for `devcheck:allow(...)` validation and docs.
pub const RULES: &[&str] = &[
    panic_free::RULE,
    ledger_order::RULE,
    codec::RULE,
    guard_io::RULE,
    wire_docs::RULE,
];

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?
        .collect::<Result<Vec<_>, _>>()?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_rs(&p, out)?;
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lex and check every file under `<root>/rust/src` plus the two wire
/// docs. Returns suppression-filtered findings sorted by (file, line).
pub fn check_repo(root: &Path) -> anyhow::Result<Vec<Finding>> {
    let src_root = root.join("rust/src");
    let mut paths = Vec::new();
    walk_rs(&src_root, &mut paths)?;

    let mut files: Vec<SourceFile> = Vec::with_capacity(paths.len());
    for p in &paths {
        let rel = p
            .strip_prefix(root)
            .unwrap_or(p)
            .to_string_lossy()
            .replace('\\', "/");
        let text = fs::read_to_string(p)?;
        files.push(SourceFile::parse(rel, &text));
    }

    let wire_md = fs::read_to_string(root.join("docs/WIRE.md"))?;
    let ops_md = fs::read_to_string(root.join("docs/OPERATIONS.md"))?;

    let mut findings = Vec::new();
    for f in &files {
        if panic_free::applies_to(&f.path) {
            findings.extend(panic_free::check(f));
        }
        if ledger_order::applies_to(&f.path) {
            findings.extend(ledger_order::check(f));
        }
        if codec::applies_to(&f.path) {
            findings.extend(codec::check(f));
        }
        if guard_io::applies_to(&f.path) {
            findings.extend(guard_io::check(f));
        }
    }
    let eval_files: Vec<&SourceFile> = files
        .iter()
        .filter(|f| f.path.starts_with("rust/src/eval/"))
        .collect();
    findings.extend(wire_docs::check(&eval_files, &wire_md, &ops_md));

    // Suppressions: source files carry theirs in the lexed model; the
    // two docs get the same text-level scan.
    let wire_allows = model::collect_allows(&wire_md);
    let ops_allows = model::collect_allows(&ops_md);
    let doc_allowed = |path: &str, rule: &str, line: usize| {
        let allows = match path {
            "docs/WIRE.md" => &wire_allows,
            "docs/OPERATIONS.md" => &ops_allows,
            _ => return false,
        };
        allows
            .iter()
            .any(|(r, l)| r == rule && (*l == line || l + 1 == line))
    };
    findings.retain(|fd| {
        if let Some(sf) = files.iter().find(|f| f.path == fd.file) {
            !sf.allowed(fd.rule, fd.line)
        } else {
            !doc_allowed(&fd.file, fd.rule, fd.line)
        }
    });

    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(findings)
}

/// CLI entry: print findings (or a clean summary) and return the exit
/// code — 1 if anything was found, 0 when clean.
pub fn run(root: &Path) -> anyhow::Result<i32> {
    let findings = check_repo(root)?;
    if findings.is_empty() {
        println!(
            "devcheck: clean ({} rules: {})",
            RULES.len(),
            RULES.join(", ")
        );
        return Ok(0);
    }
    for f in &findings {
        println!("{}", f.render());
    }
    println!("devcheck: {} finding(s)", findings.len());
    Ok(1)
}
