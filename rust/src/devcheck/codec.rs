//! Rule `codec-discipline`: the measurement wire and journal hot paths
//! use the zero-copy streaming codec. The allocating tree parser
//! (`Json::parse`) is reserved for *named lenient-fallback functions* —
//! the compatibility escape hatches that accept frames from older or
//! foreign writers. Anywhere else in the codec files it is a hot-path
//! regression.

use super::model::SourceFile;
use super::Finding;

pub const RULE: &str = "codec-discipline";

/// (file, functions where the tree parser is the designated fallback).
pub const ALLOWED: &[(&str, &[&str])] = &[
    (
        "rust/src/eval/proto.rs",
        &[
            "read_frame",
            "record_from_line",
            "record_identity_from_line",
            "request_from_line",
            "response_from_line",
        ],
    ),
    (
        "rust/src/eval/journal.rs",
        &["check_header", "refuse_if_v1", "compact_journal"],
    ),
    (
        "rust/src/eval/tune_proto.rs",
        &["tune_request_from_line", "tune_response_from_line"],
    ),
    ("rust/src/eval/remote.rs", &[]),
    ("rust/src/eval/server.rs", &[]),
    ("rust/src/eval/tune_server.rs", &[]),
];

pub fn applies_to(path: &str) -> bool {
    ALLOWED.iter().any(|(f, _)| *f == path)
}

fn allowed_fns(path: &str) -> &'static [&'static str] {
    ALLOWED
        .iter()
        .find(|(f, _)| *f == path)
        .map(|(_, fns)| *fns)
        .unwrap_or(&[])
}

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let allowed = allowed_fns(&file.path);
    let mut out = Vec::new();
    for i in 0..file.tokens.len() {
        if file.excluded[i] {
            continue;
        }
        // Json :: parse
        let hit = file.tokens[i].is_ident("Json")
            && file.tokens.get(i + 1).is_some_and(|t| t.is_punct(':'))
            && file.tokens.get(i + 2).is_some_and(|t| t.is_punct(':'))
            && file.tokens.get(i + 3).is_some_and(|t| t.is_ident("parse"));
        if !hit {
            continue;
        }
        let in_fallback = file
            .enclosing_fn(i)
            .is_some_and(|f| allowed.contains(&f.name.as_str()));
        if in_fallback {
            continue;
        }
        let where_ = file
            .enclosing_fn(i)
            .map(|f| format!("`{}`", f.name))
            .unwrap_or_else(|| "module scope".to_string());
        out.push(Finding {
            rule: RULE,
            file: file.path.clone(),
            line: file.tokens[i].line,
            message: format!(
                "tree `Json::parse` in {where_} — hot-path codec files must \
                 stream; tree parsing belongs only in the named lenient-fallback \
                 functions"
            ),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_outside_fallback_is_flagged() {
        let f = SourceFile::parse(
            "rust/src/eval/proto.rs".to_string(),
            "fn hot_path(line: &str) { let v = Json::parse(line); }",
        );
        let fs = check(&f);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].message.contains("hot_path"));
    }

    #[test]
    fn parse_inside_named_fallback_is_allowed() {
        let f = SourceFile::parse(
            "rust/src/eval/proto.rs".to_string(),
            "fn request_from_line(line: &str) { let v = Json::parse(line); }",
        );
        assert!(check(&f).is_empty());
    }
}
