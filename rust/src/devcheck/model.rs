//! Shared source model for the devcheck rules: a lexed file plus the
//! structure every rule needs — function spans, `#[cfg(test)]` regions
//! (exempt from all rules) and `// devcheck:allow(<rule>)` suppressions.

use super::lexer::{lex, Token};
use std::collections::BTreeSet;

/// One function's token span: `tokens[body_start..=body_end]` is the
/// body including both braces.
#[derive(Debug, Clone)]
pub struct FnSpan {
    pub name: String,
    pub line: usize,
    pub body_start: usize,
    pub body_end: usize,
}

/// A lexed source file plus rule-relevant structure.
pub struct SourceFile {
    /// Repo-relative path with forward slashes (`rust/src/eval/...`).
    pub path: String,
    pub tokens: Vec<Token>,
    /// `excluded[i]` — token i sits inside a `#[cfg(test)]` item.
    pub excluded: Vec<bool>,
    pub fns: Vec<FnSpan>,
    /// Lines carrying a `devcheck:allow(<rule>)` marker, per rule. A
    /// marker suppresses that rule on its own line and the next line.
    allows: Vec<(String, usize)>,
}

impl SourceFile {
    pub fn parse(path: String, text: &str) -> SourceFile {
        let tokens = lex(text);
        let excluded = mark_cfg_test(&tokens);
        let fns = fn_spans(&tokens);
        let allows = collect_allows(text);
        SourceFile { path, tokens, excluded, fns, allows }
    }

    /// The innermost function span containing token `i`, by name.
    pub fn enclosing_fn(&self, i: usize) -> Option<&FnSpan> {
        self.fns
            .iter()
            .filter(|f| f.body_start <= i && i <= f.body_end)
            .min_by_key(|f| f.body_end - f.body_start)
    }

    /// Is the finding at `line` suppressed for `rule` by an inline
    /// `devcheck:allow(rule)` marker on the same or previous line?
    pub fn allowed(&self, rule: &str, line: usize) -> bool {
        self.allows
            .iter()
            .any(|(r, l)| r == rule && (*l == line || l + 1 == line))
    }
}

/// Scan the raw text for allow markers. Text-level (not token-level) on
/// purpose: the marker lives in comments, which the lexer drops. Also
/// used directly on markdown files, where no lexing happens at all.
pub fn collect_allows(text: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let marker = "devcheck:allow(";
    for (idx, line) in text.lines().enumerate() {
        let mut rest = line;
        while let Some(at) = rest.find(marker) {
            let tail = &rest[at + marker.len()..];
            if let Some(end) = tail.find(')') {
                out.push((tail[..end].trim().to_string(), idx + 1));
                rest = &tail[end..];
            } else {
                break;
            }
        }
    }
    out
}

/// Mark every token inside a `#[cfg(test)]` item. The item is whatever
/// follows the attribute (and any further attributes): a `mod`/`fn`/
/// `impl` block through its matching brace, or a braceless item through
/// its `;`.
fn mark_cfg_test(tokens: &[Token]) -> Vec<bool> {
    let mut excluded = vec![false; tokens.len()];
    let mut i = 0usize;
    while i < tokens.len() {
        if is_cfg_test_attr(tokens, i) {
            // Skip this attribute and any stacked ones.
            let mut j = skip_attr(tokens, i);
            while j < tokens.len() && tokens[j].is_punct('#') {
                j = skip_attr(tokens, j);
            }
            // The guarded item: brace block or `;`-terminated.
            let mut depth = 0usize;
            let mut k = j;
            while k < tokens.len() {
                if tokens[k].is_punct('{') {
                    depth += 1;
                } else if tokens[k].is_punct('}') {
                    depth -= 1;
                    if depth == 0 {
                        break;
                    }
                } else if tokens[k].is_punct(';') && depth == 0 {
                    break;
                }
                k += 1;
            }
            let end = k.min(tokens.len().saturating_sub(1));
            for flag in excluded.iter_mut().take(end + 1).skip(i) {
                *flag = true;
            }
            i = end + 1;
        } else {
            i += 1;
        }
    }
    excluded
}

/// Does `#[cfg(test)]` (optionally `#[cfg(any(test, ...))]`) start at
/// token `i`?
fn is_cfg_test_attr(tokens: &[Token], i: usize) -> bool {
    if !(i + 4 < tokens.len()
        && tokens[i].is_punct('#')
        && tokens[i + 1].is_punct('[')
        && tokens[i + 2].is_ident("cfg"))
    {
        return false;
    }
    // Anything of the form cfg(...test...) is treated as test-gated.
    let mut j = i + 3;
    let mut depth = 0usize;
    while j < tokens.len() {
        if tokens[j].is_punct('[') || tokens[j].is_punct('(') {
            depth += 1;
        } else if tokens[j].is_punct(']') && depth == 0 {
            return false;
        } else if tokens[j].is_punct(')') || tokens[j].is_punct(']') {
            depth = depth.saturating_sub(1);
        } else if tokens[j].is_ident("test") {
            return true;
        }
        j += 1;
    }
    false
}

/// Index just past an attribute starting at `#` token `i`.
fn skip_attr(tokens: &[Token], i: usize) -> usize {
    let mut j = i + 1;
    if j < tokens.len() && tokens[j].is_punct('!') {
        j += 1;
    }
    if j >= tokens.len() || !tokens[j].is_punct('[') {
        return i + 1;
    }
    let mut depth = 0usize;
    while j < tokens.len() {
        if tokens[j].is_punct('[') {
            depth += 1;
        } else if tokens[j].is_punct(']') {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
        j += 1;
    }
    tokens.len()
}

/// Every `fn name ... { body }` span, including nested functions.
/// Bodiless declarations (trait methods) are skipped, as are `fn`
/// tokens not followed by a name (`fn(...)` pointer types).
fn fn_spans(tokens: &[Token]) -> Vec<FnSpan> {
    let mut out = Vec::new();
    for i in 0..tokens.len() {
        if !tokens[i].is_ident("fn") {
            continue;
        }
        let Some(name) = tokens.get(i + 1).and_then(|t| t.ident()) else {
            continue;
        };
        // Find the body `{` — or a `;` first for bodiless declarations.
        // Angle brackets in generics/returns can contain parens but not
        // braces, so scanning for the first `{`/top-level `;` is sound.
        let mut j = i + 2;
        let mut paren = 0usize;
        let mut body_start = None;
        while j < tokens.len() {
            if tokens[j].is_punct('(') {
                paren += 1;
            } else if tokens[j].is_punct(')') {
                paren = paren.saturating_sub(1);
            } else if tokens[j].is_punct('{') {
                body_start = Some(j);
                break;
            } else if tokens[j].is_punct(';') && paren == 0 {
                break;
            }
            j += 1;
        }
        let Some(start) = body_start else {
            continue;
        };
        let mut depth = 0usize;
        let mut end = start;
        while end < tokens.len() {
            if tokens[end].is_punct('{') {
                depth += 1;
            } else if tokens[end].is_punct('}') {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            end += 1;
        }
        out.push(FnSpan {
            name: name.to_string(),
            line: tokens[i].line,
            body_start: start,
            body_end: end.min(tokens.len().saturating_sub(1)),
        });
    }
    out
}

/// Names of functions whose bodies contain token `i` — outermost first.
pub fn enclosing_fn_names(file: &SourceFile, i: usize) -> BTreeSet<String> {
    file.fns
        .iter()
        .filter(|f| f.body_start <= i && i <= f.body_end)
        .map(|f| f.name.clone())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_test_mods_are_excluded() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests { fn t() { y.unwrap(); } }";
        let f = SourceFile::parse("a.rs".to_string(), src);
        let unwraps: Vec<bool> = f
            .tokens
            .iter()
            .zip(&f.excluded)
            .filter(|(t, _)| t.is_ident("unwrap"))
            .map(|(_, e)| *e)
            .collect();
        assert_eq!(unwraps, vec![false, true]);
    }

    #[test]
    fn fn_spans_nest_and_name_correctly() {
        let src = "fn outer() { fn inner() { a(); } inner(); }";
        let f = SourceFile::parse("a.rs".to_string(), src);
        assert_eq!(f.fns.len(), 2);
        let a_idx = f.tokens.iter().position(|t| t.is_ident("a")).unwrap();
        assert_eq!(f.enclosing_fn(a_idx).unwrap().name, "inner");
    }

    #[test]
    fn allow_markers_cover_their_line_and_the_next() {
        let src = "line1\n// devcheck:allow(panic-free)\nflagged_here\nnot_here";
        let f = SourceFile::parse("a.rs".to_string(), src);
        assert!(f.allowed("panic-free", 2));
        assert!(f.allowed("panic-free", 3));
        assert!(!f.allowed("panic-free", 4));
        assert!(!f.allowed("ledger-order", 3));
    }
}
