//! Rule `panic-free`: the daemon and wire modules must not contain a
//! reachable panic. A poisoned lock, a malformed frame or a dead peer
//! takes down one connection (or returns a structured error reply) —
//! never the process serving every other client.
//!
//! Banned in non-test code: `.unwrap()`, `.expect(...)`, `panic!`,
//! `unreachable!`, `todo!`, `unimplemented!`. The designated
//! poisoned-lock helpers in `eval/sync.rs` (`lock_unpoisoned`,
//! `wait_unpoisoned`) are the one place allowed to touch the poison
//! `Result`, and `sync::raise` is the one sanctioned panic (infallible
//! trait facades with no error channel) — their bodies are exempt.

use super::model::SourceFile;
use super::Finding;

pub const RULE: &str = "panic-free";

/// Files the rule applies to (repo-relative).
pub const CHECKED_FILES: &[&str] = &[
    "rust/src/eval/server.rs",
    "rust/src/eval/tune_server.rs",
    "rust/src/eval/remote.rs",
    "rust/src/eval/tune_client.rs",
    "rust/src/eval/sync.rs",
    "rust/src/eval/engine.rs",
    "rust/src/eval/ledger.rs",
    "rust/src/eval/cache.rs",
    "rust/src/eval/store.rs",
    "rust/src/eval/calib.rs",
];

/// The designated poisoned-lock helpers plus the sanctioned panic escape
/// hatch: the only function bodies in the checked set where the panic
/// family is permitted.
const ALLOWED_FNS: &[&str] = &["lock_unpoisoned", "wait_unpoisoned", "raise"];

const BANNED_METHODS: &[&str] = &["unwrap", "expect"];
const BANNED_MACROS: &[&str] = &["panic", "unreachable", "todo", "unimplemented"];

pub fn applies_to(path: &str) -> bool {
    CHECKED_FILES.contains(&path)
}

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, tok) in file.tokens.iter().enumerate() {
        if file.excluded[i] {
            continue;
        }
        let Some(name) = tok.ident() else { continue };
        let is_method = BANNED_METHODS.contains(&name)
            && i > 0
            && file.tokens[i - 1].is_punct('.');
        let is_macro = BANNED_MACROS.contains(&name)
            && file.tokens.get(i + 1).is_some_and(|t| t.is_punct('!'));
        if !(is_method || is_macro) {
            continue;
        }
        if let Some(f) = file.enclosing_fn(i) {
            if ALLOWED_FNS.contains(&f.name.as_str()) {
                continue;
            }
        }
        let what = if is_macro {
            format!("{name}!")
        } else {
            format!(".{name}()")
        };
        out.push(Finding {
            rule: RULE,
            file: file.path.clone(),
            line: tok.line,
            message: format!(
                "`{what}` can panic a daemon thread; return a structured error \
                 or route lock poisoning through eval/sync.rs"
            ),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> SourceFile {
        SourceFile::parse("rust/src/eval/server.rs".to_string(), src)
    }

    #[test]
    fn flags_unwrap_and_panic_macros() {
        let f = parse("fn a() { x.unwrap(); panic!(\"boom\"); }");
        let rules: Vec<usize> = check(&f).iter().map(|f| f.line).collect();
        assert_eq!(rules.len(), 2);
    }

    #[test]
    fn designated_helpers_are_exempt() {
        let f = parse("fn lock_unpoisoned() { m.lock().unwrap(); }");
        assert!(check(&f).is_empty());
    }

    #[test]
    fn bare_idents_and_tests_do_not_trip() {
        // `unwrap_or_else` is a distinct token; `expect` without a
        // leading dot is just a word; cfg(test) code is exempt.
        let f = parse(
            "fn a() { x.unwrap_or_else(f); }\n\
             #[cfg(test)] mod t { fn b() { y.unwrap(); } }",
        );
        assert!(check(&f).is_empty());
    }
}
