//! A small token-level Rust lexer for the devcheck lints.
//!
//! This is deliberately *not* a parser: the lints below only need a
//! stream of identifiers, punctuation and string literals with correct
//! line numbers, where nothing inside a string, char literal, raw
//! string or comment can masquerade as code. Handling exactly those
//! four confusables correctly is the whole job — `"a.unwrap()"` in an
//! error message, `'{'` as a char literal, `r#"{"op":"ping"}"#` test
//! payloads and commented-out code must all be invisible to the rules.
//!
//! Numbers, lifetimes and multi-character operators are kept only as
//! far as the rules need them (`=>` stays two puncts; the rules match
//! the `=`,`>` pair).

/// One lexed token. Strings carry their *cooked* contents (escapes
/// resolved), so rules compare against what the program would print.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Tok {
    /// Identifier or keyword (`fn`, `unwrap`, `Json`, ...).
    Ident(String),
    /// String literal contents — cooked for `"..."`, verbatim for raw
    /// strings. The quotes and `r#` framing are stripped.
    Str(String),
    /// Char or byte-char literal (contents irrelevant to the rules).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// Numeric literal (contents irrelevant to the rules).
    Num,
    /// Any other single character (`.`, `(`, `{`, `!`, `=`, `>`, ...).
    Punct(char),
}

/// A token plus the 1-based source line it starts on.
#[derive(Debug, Clone)]
pub struct Token {
    pub tok: Tok,
    pub line: usize,
}

impl Token {
    /// The identifier text, if this token is an identifier.
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    /// The string-literal contents, if this token is a string.
    pub fn str_lit(&self) -> Option<&str> {
        match &self.tok {
            Tok::Str(s) => Some(s),
            _ => None,
        }
    }

    /// True when this token is exactly the punctuation `c`.
    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }

    /// True when this token is exactly the identifier `s`.
    pub fn is_ident(&self, s: &str) -> bool {
        matches!(&self.tok, Tok::Ident(t) if t.as_str() == s)
    }
}

/// Lex `src` into a token stream. Unterminated constructs consume to
/// end of input rather than erroring — a lint pass must never die on
/// the code it is judging.
pub fn lex(src: &str) -> Vec<Token> {
    Lexer { chars: src.chars().collect(), pos: 0, line: 1, out: Vec::new() }.run()
}

struct Lexer {
    chars: Vec<char>,
    pos: usize,
    line: usize,
    out: Vec<Token>,
}

impl Lexer {
    fn peek(&self, ahead: usize) -> Option<char> {
        self.chars.get(self.pos + ahead).copied()
    }

    /// Consume one char, tracking line numbers.
    fn bump(&mut self) -> Option<char> {
        let c = self.chars.get(self.pos).copied();
        if let Some(ch) = c {
            self.pos += 1;
            if ch == '\n' {
                self.line += 1;
            }
        }
        c
    }

    fn push(&mut self, tok: Tok, line: usize) {
        self.out.push(Token { tok, line });
    }

    fn run(mut self) -> Vec<Token> {
        while let Some(c) = self.peek(0) {
            let line = self.line;
            match c {
                ch if ch.is_whitespace() => {
                    self.bump();
                }
                '/' if self.peek(1) == Some('/') => self.line_comment(),
                '/' if self.peek(1) == Some('*') => self.block_comment(),
                '"' => {
                    self.bump();
                    let s = self.cooked_string();
                    self.push(Tok::Str(s), line);
                }
                'r' if matches!(self.peek(1), Some('"') | Some('#')) && self.raw_ahead(1) => {
                    self.bump();
                    let s = self.raw_string();
                    self.push(Tok::Str(s), line);
                }
                'b' => self.byte_prefixed(line),
                '\'' => self.quote(line),
                ch if ch.is_alphabetic() || ch == '_' => {
                    let s = self.ident();
                    self.push(Tok::Ident(s), line);
                }
                ch if ch.is_ascii_digit() => {
                    self.number();
                    self.push(Tok::Num, line);
                }
                ch => {
                    self.bump();
                    self.push(Tok::Punct(ch), line);
                }
            }
        }
        self.out
    }

    fn line_comment(&mut self) {
        while let Some(c) = self.peek(0) {
            if c == '\n' {
                break;
            }
            self.bump();
        }
    }

    /// Rust block comments nest.
    fn block_comment(&mut self) {
        self.bump();
        self.bump();
        let mut depth = 1usize;
        while depth > 0 {
            match (self.peek(0), self.peek(1)) {
                (Some('/'), Some('*')) => {
                    self.bump();
                    self.bump();
                    depth += 1;
                }
                (Some('*'), Some('/')) => {
                    self.bump();
                    self.bump();
                    depth -= 1;
                }
                (Some(_), _) => {
                    self.bump();
                }
                (None, _) => break,
            }
        }
    }

    /// Contents of a `"..."` literal, opening quote already consumed.
    /// Common escapes are cooked; `\` + newline (line continuation)
    /// swallows the newline and leading whitespace like rustc does.
    fn cooked_string(&mut self) -> String {
        let mut s = String::new();
        loop {
            match self.bump() {
                None | Some('"') => break,
                Some('\\') => match self.bump() {
                    Some('n') => s.push('\n'),
                    Some('r') => s.push('\r'),
                    Some('t') => s.push('\t'),
                    Some('0') => s.push('\0'),
                    Some('\\') => s.push('\\'),
                    Some('"') => s.push('"'),
                    Some('\'') => s.push('\''),
                    Some('\n') => {
                        while matches!(self.peek(0), Some(c) if c.is_whitespace()) {
                            self.bump();
                        }
                    }
                    Some('u') => {
                        // \u{...}: decode if well-formed, else keep raw.
                        let mut hex = String::new();
                        if self.peek(0) == Some('{') {
                            self.bump();
                            while let Some(c) = self.peek(0) {
                                self.bump();
                                if c == '}' {
                                    break;
                                }
                                hex.push(c);
                            }
                        }
                        match u32::from_str_radix(&hex, 16).ok().and_then(char::from_u32) {
                            Some(c) => s.push(c),
                            None => s.push_str(&hex),
                        }
                    }
                    Some(other) => s.push(other),
                    None => break,
                },
                Some(c) => s.push(c),
            }
        }
        s
    }

    /// Is `r`/`br` at `self.pos + offset` really a raw string head —
    /// zero or more `#` then `"`?
    fn raw_ahead(&self, offset: usize) -> bool {
        let mut i = offset;
        while self.peek(i) == Some('#') {
            i += 1;
        }
        self.peek(i) == Some('"')
    }

    /// Contents of a raw string; `r` already consumed, `#…"` not yet.
    fn raw_string(&mut self) -> String {
        let mut hashes = 0usize;
        while self.peek(0) == Some('#') {
            self.bump();
            hashes += 1;
        }
        self.bump(); // opening quote
        let mut s = String::new();
        'outer: while let Some(c) = self.bump() {
            if c == '"' {
                // A quote closes only when followed by `hashes` hashes.
                for i in 0..hashes {
                    if self.peek(i) != Some('#') {
                        s.push('"');
                        continue 'outer;
                    }
                }
                for _ in 0..hashes {
                    self.bump();
                }
                break;
            }
            s.push(c);
        }
        s
    }

    /// `b"..."`, `br"..."`, `b'.'` — or just an identifier starting
    /// with `b`.
    fn byte_prefixed(&mut self, line: usize) {
        match self.peek(1) {
            Some('"') => {
                self.bump();
                self.bump();
                let s = self.cooked_string();
                self.push(Tok::Str(s), line);
            }
            Some('\'') => {
                self.bump();
                self.bump();
                self.char_body();
                self.push(Tok::Char, line);
            }
            Some('r') if self.raw_ahead(2) => {
                self.bump();
                self.bump();
                let s = self.raw_string();
                self.push(Tok::Str(s), line);
            }
            _ => {
                let s = self.ident();
                self.push(Tok::Ident(s), line);
            }
        }
    }

    /// `'` starts either a char literal or a lifetime. A backslash or a
    /// single char followed by `'` is a char literal; otherwise it is a
    /// lifetime (`'a`, `'static`).
    fn quote(&mut self, line: usize) {
        self.bump();
        if self.peek(0) == Some('\\') || self.peek(1) == Some('\'') {
            self.char_body();
            self.push(Tok::Char, line);
        } else {
            while matches!(self.peek(0), Some(c) if c.is_alphanumeric() || c == '_') {
                self.bump();
            }
            self.push(Tok::Lifetime, line);
        }
    }

    /// Consume a char-literal body up to and including the closing `'`
    /// (opening quote already consumed).
    fn char_body(&mut self) {
        loop {
            match self.bump() {
                None | Some('\'') => break,
                Some('\\') => {
                    self.bump();
                }
                Some(_) => {}
            }
        }
    }

    fn ident(&mut self) -> String {
        let mut s = String::new();
        while matches!(self.peek(0), Some(c) if c.is_alphanumeric() || c == '_') {
            s.push(self.bump().unwrap_or('_'));
        }
        s
    }

    /// Numeric literal: digits plus suffix/exponent chars. `..` after a
    /// number (`0..n`) must stay punctuation, so a dot is consumed only
    /// when it is not itself followed by a dot.
    fn number(&mut self) {
        while let Some(c) = self.peek(0) {
            if c.is_alphanumeric() || c == '_' {
                self.bump();
            } else if c == '.' && matches!(self.peek(1), Some(d) if d.is_ascii_digit()) {
                self.bump();
            } else {
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .into_iter()
            .filter_map(|t| match t.tok {
                Tok::Ident(s) => Some(s),
                _ => None,
            })
            .collect()
    }

    #[test]
    fn strings_hide_their_contents_from_the_ident_stream() {
        let src = r#"let msg = "please do not unwrap() here"; msg.len();"#;
        let ids = idents(src);
        assert!(!ids.contains(&"unwrap".to_string()), "ident leaked out of a string: {ids:?}");
        assert!(ids.contains(&"len".to_string()));
    }

    #[test]
    fn escapes_are_cooked_and_line_numbers_survive() {
        let src = "let a = \"x\\n\\\"y\\\"\";\nlet b = 1;";
        let toks = lex(src);
        let s = toks.iter().find_map(|t| t.str_lit()).unwrap();
        assert_eq!(s, "x\n\"y\"");
        let b = toks.iter().find(|t| t.is_ident("b")).unwrap();
        assert_eq!(b.line, 2);
    }

    #[test]
    fn raw_strings_with_hashes_do_not_end_early() {
        let src = r###"let j = r#"{"op":"ping","q":"a\"b"}"#; j.parse();"###;
        let toks = lex(src);
        let s = toks.iter().find_map(|t| t.str_lit()).unwrap();
        assert_eq!(s, r#"{"op":"ping","q":"a\"b"}"#);
        assert!(toks.iter().any(|t| t.is_ident("parse")));
    }

    #[test]
    fn char_literals_and_lifetimes_disambiguate() {
        let src = "fn f<'a>(x: &'a str) -> char { let c = '{'; let q = '\\''; c }";
        let toks = lex(src);
        let chars = toks.iter().filter(|t| t.tok == Tok::Char).count();
        let lifetimes = toks.iter().filter(|t| t.tok == Tok::Lifetime).count();
        assert_eq!(chars, 2, "{toks:?}");
        assert_eq!(lifetimes, 2, "{toks:?}");
        // The brace inside '{' must not unbalance the real braces.
        let open = toks.iter().filter(|t| t.is_punct('{')).count();
        let close = toks.iter().filter(|t| t.is_punct('}')).count();
        assert_eq!(open, close);
    }

    #[test]
    fn comments_including_nested_blocks_vanish() {
        let src = "a(); // x.unwrap()\n/* outer /* inner.expect() */ still comment */ b();";
        let ids = idents(src);
        assert_eq!(ids, vec!["a".to_string(), "b".to_string()]);
    }

    #[test]
    fn line_continuation_swallows_indentation() {
        let src = "let s = \"one \\\n         two\";";
        let toks = lex(src);
        assert_eq!(toks.iter().find_map(|t| t.str_lit()).unwrap(), "one two");
    }

    #[test]
    fn byte_and_raw_byte_strings_lex_as_strings() {
        let src = r##"w.write_all(b"\n")?; let r = br#"raw"#;"##;
        let toks = lex(src);
        let strs: Vec<&str> = toks.iter().filter_map(|t| t.str_lit()).collect();
        assert_eq!(strs, vec!["\n", "raw"]);
    }

    #[test]
    fn numeric_ranges_keep_their_dots() {
        let toks = lex("for i in 0..10 { v[i] = 2.5; }");
        let dots = toks.iter().filter(|t| t.is_punct('.')).count();
        assert_eq!(dots, 2, "0..10 must lex as Num Punct(.) Punct(.) Num: {toks:?}");
    }
}
