//! Rule `ledger-order`: the equal-budget protocol ("measure once,
//! charge everyone") only holds if every tuning-path batch is charged
//! to the [`crate::eval::BudgetLedger`] *before* it is submitted to the
//! engine, and settled only *after* results come back.
//!
//! Mechanically: in any function (outside `eval/engine.rs`, which owns
//! the batch API) that calls `submit_batch`, `measure_batch*` or
//! `screen_batch` (the multi-fidelity screening split — admitted
//! candidates leave the simulator path there, so the admission must
//! already be on the books), a `charge(...)`/`charge_screen(...)` call
//! must lexically precede the submission and no `settle(...)` call may
//! precede it.

use super::model::SourceFile;
use super::Finding;

pub const RULE: &str = "ledger-order";

/// The engine module defines the batch API; calls inside it are the
/// implementation, not tuning-path submissions.
const DEFINING_FILE: &str = "rust/src/eval/engine.rs";

pub fn applies_to(path: &str) -> bool {
    path.starts_with("rust/src/") && path.ends_with(".rs") && path != DEFINING_FILE
}

fn is_submit_name(name: &str) -> bool {
    name == "submit_batch" || name == "screen_batch" || name.starts_with("measure_batch")
}

/// Charge-family calls that admit points against the ledger before a
/// submission: plain admission, or the screening tier's own settlement
/// (which may only run on already-admitted points).
fn is_charge_name(name: &str) -> bool {
    name == "charge" || name == "charge_screen"
}

/// A call (not a definition): `name` followed by `(`, not preceded by
/// `fn`, and not a path segment being defined (`fn measure_batch`).
fn is_call(file: &SourceFile, i: usize) -> bool {
    file.tokens.get(i + 1).is_some_and(|t| t.is_punct('('))
        && !(i > 0 && file.tokens[i - 1].is_ident("fn"))
}

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (i, tok) in file.tokens.iter().enumerate() {
        if file.excluded[i] {
            continue;
        }
        let Some(name) = tok.ident() else { continue };
        if !is_submit_name(name) || !is_call(file, i) {
            continue;
        }
        let Some(f) = file.enclosing_fn(i) else { continue };
        let mut saw_charge = false;
        let mut settle_line = None;
        for j in f.body_start..i {
            if let Some(n) = file.tokens[j].ident() {
                if is_charge_name(n) && is_call(file, j) {
                    saw_charge = true;
                } else if n == "settle" && is_call(file, j) {
                    settle_line = Some(file.tokens[j].line);
                }
            }
        }
        if !saw_charge {
            out.push(Finding {
                rule: RULE,
                file: file.path.clone(),
                line: tok.line,
                message: format!(
                    "`{name}` submits measurements in `{}` with no preceding \
                     `charge(...)` — the batch bypasses the budget ledger",
                    f.name
                ),
            });
        } else if let Some(sl) = settle_line {
            out.push(Finding {
                rule: RULE,
                file: file.path.clone(),
                line: tok.line,
                message: format!(
                    "`settle(...)` on line {sl} precedes `{name}` in `{}` — \
                     settlement must follow the submission it pays for",
                    f.name
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> SourceFile {
        SourceFile::parse("rust/src/tuner/task_tuner.rs".to_string(), src)
    }

    #[test]
    fn charge_before_submit_is_clean() {
        let f = parse("fn tune() { ledger.charge(a); engine.submit_batch(b); ledger.settle(c); }");
        assert!(check(&f).is_empty());
    }

    #[test]
    fn missing_charge_is_flagged() {
        let f = parse("fn tune() { engine.measure_batch_traced(b); }");
        let fs = check(&f);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].message.contains("no preceding `charge"));
    }

    #[test]
    fn settle_before_submit_is_flagged() {
        let f = parse("fn tune() { ledger.charge(a); ledger.settle(c); engine.submit_batch(b); }");
        let fs = check(&f);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].message.contains("settlement must follow"));
    }

    #[test]
    fn definitions_do_not_trip() {
        let f = parse("impl Engine { fn submit_batch(&self) { inner(); } }");
        assert!(check(&f).is_empty());
    }

    #[test]
    fn screen_split_requires_a_preceding_charge() {
        // The multi-fidelity screening split diverts admitted candidates
        // away from the simulator; doing it before admission would let
        // low-fidelity points bypass the budget entirely.
        let f = parse("fn tune() { let split = screen_batch(space, plan); }");
        let fs = check(&f);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].message.contains("`screen_batch`"));
        assert!(fs[0].message.contains("no preceding `charge"));

        let clean =
            parse("fn tune() { ledger.charge(a); let split = screen_batch(space, plan); }");
        assert!(check(&clean).is_empty());
        // A definition of the split helper is not a submission.
        let def = parse("fn screen_batch(space: &S, plan: Vec<P>) -> Split { rank(plan) }");
        assert!(check(&def).is_empty());
    }

    #[test]
    fn charge_screen_counts_as_a_charge() {
        let f = parse("fn tune() { ledger.charge_screen(a); engine.submit_batch(b); }");
        assert!(check(&f).is_empty());
    }
}
