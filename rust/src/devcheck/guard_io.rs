//! Rule `guard-io`: a live `MutexGuard` must not span a socket write or
//! other blocking I/O. A slow or dead peer would hold the lock for the
//! whole daemon — every other connection stalls behind one client's TCP
//! window.
//!
//! Heuristic: a `let` binding whose initializer calls `lock`,
//! `try_lock`, `lock_unpoisoned` or `wait_unpoisoned` is treated as a
//! guard. While that binding is in scope (until its block closes or an
//! explicit `drop(name)`), any token naming a known I/O entry point is
//! flagged.

use super::model::SourceFile;
use super::Finding;

pub const RULE: &str = "guard-io";

pub const CHECKED_FILES: &[&str] = &[
    "rust/src/eval/server.rs",
    "rust/src/eval/tune_server.rs",
    "rust/src/eval/remote.rs",
    "rust/src/eval/tune_client.rs",
];

/// Calls whose result is (or contains) a lock guard.
const GUARD_SOURCES: &[&str] = &["lock", "try_lock", "lock_unpoisoned", "wait_unpoisoned"];

/// Free functions that hit the wire.
const IO_FNS: &[&str] = &[
    "write_frame",
    "write_request_frame",
    "write_response_frame",
    "write_tune_request_frame",
    "write_tune_response_frame",
    "write_record_line",
    "read_frame",
    "read_frame_line",
];

/// Methods that hit the wire (flagged as `.name(`).
const IO_METHODS: &[&str] = &["write_all", "write_fmt", "flush", "read_line", "read_exact"];

pub fn applies_to(path: &str) -> bool {
    CHECKED_FILES.contains(&path)
}

struct Guard {
    name: String,
    depth: usize,
    line: usize,
}

pub fn check(file: &SourceFile) -> Vec<Finding> {
    let toks = &file.tokens;
    let mut out = Vec::new();
    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0usize;
    let mut i = 0usize;
    while i < toks.len() {
        if file.excluded[i] {
            i += 1;
            continue;
        }
        let t = &toks[i];
        if t.is_punct('{') {
            depth += 1;
        } else if t.is_punct('}') {
            depth = depth.saturating_sub(1);
            guards.retain(|g| g.depth <= depth);
        } else if t.is_ident("let")
            && !(i > 0 && (toks[i - 1].is_ident("if") || toks[i - 1].is_ident("while")))
        {
            // `let [mut] name = <init> ;` — guard if the initializer
            // calls one of the guard sources. `if let`/`while let` are
            // pattern matches, not bindings this scan can track.
            let mut j = i + 1;
            if toks.get(j).is_some_and(|t| t.is_ident("mut")) {
                j += 1;
            }
            if let Some(name) = toks.get(j).and_then(|t| t.ident()) {
                let name = name.to_string();
                // Scan the initializer to its `;` at the current depth.
                let mut k = j + 1;
                let mut d = 0usize;
                let mut is_guard = false;
                while k < toks.len() {
                    let tk = &toks[k];
                    if tk.is_punct('{') || tk.is_punct('(') || tk.is_punct('[') {
                        d += 1;
                    } else if tk.is_punct('}') || tk.is_punct(')') || tk.is_punct(']') {
                        if d == 0 {
                            break;
                        }
                        d -= 1;
                    } else if tk.is_punct(';') && d == 0 {
                        break;
                    } else if d == 0
                        && tk.ident().is_some_and(|n| GUARD_SOURCES.contains(&n))
                        && toks.get(k + 1).is_some_and(|t| t.is_punct('('))
                    {
                        // Depth 0 only: a lock taken inside a nested
                        // block (`let v = { let g = lock(...); ... };`)
                        // is dropped before the binding exists.
                        is_guard = true;
                    }
                    k += 1;
                }
                if is_guard {
                    guards.push(Guard { name, depth, line: t.line });
                }
                i = k;
                continue;
            }
        } else if t.is_ident("drop")
            && toks.get(i + 1).is_some_and(|t| t.is_punct('('))
        {
            if let Some(dropped) = toks.get(i + 2).and_then(|t| t.ident()) {
                guards.retain(|g| g.name != dropped);
            }
        } else if let Some(name) = t.ident() {
            let is_io_fn = IO_FNS.contains(&name)
                && toks.get(i + 1).is_some_and(|t| t.is_punct('('));
            let is_io_method = IO_METHODS.contains(&name)
                && i > 0
                && toks[i - 1].is_punct('.');
            if (is_io_fn || is_io_method) && !guards.is_empty() {
                let g = guards.last().expect("non-empty");
                out.push(Finding {
                    rule: RULE,
                    file: file.path.clone(),
                    line: t.line,
                    message: format!(
                        "`{name}` performs I/O while lock guard `{}` (line {}) \
                         is live — drop the guard before touching the wire",
                        g.name, g.line
                    ),
                });
            }
        }
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(src: &str) -> SourceFile {
        SourceFile::parse("rust/src/eval/server.rs".to_string(), src)
    }

    #[test]
    fn io_under_guard_is_flagged() {
        let f = parse(
            "fn serve() { let st = state.lock().unwrap(); \
             write_frame(&mut out, &resp); }",
        );
        let fs = check(&f);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].message.contains("`st`"));
    }

    #[test]
    fn guard_scoped_to_inner_block_is_fine() {
        let f = parse(
            "fn serve() { { let st = lock_unpoisoned(&state); st.bump(); } \
             write_frame(&mut out, &resp); }",
        );
        assert!(check(&f).is_empty());
    }

    #[test]
    fn lock_inside_a_block_initializer_is_not_a_guard() {
        let f = parse(
            "fn serve() { let resp = { let g = lock_unpoisoned(&s); g.val() }; \
             write_frame(&mut out, &resp); }",
        );
        assert!(check(&f).is_empty());
    }

    #[test]
    fn explicit_drop_releases_the_guard() {
        let f = parse(
            "fn serve() { let st = state.lock().unwrap(); drop(st); \
             out.write_all(b\"x\"); }",
        );
        assert!(check(&f).is_empty());
    }

    #[test]
    fn plain_bindings_are_not_guards() {
        let f = parse("fn serve() { let n = count(); out.flush(); }");
        assert!(check(&f).is_empty());
    }
}
