//! Rule `wire-docs`: docs/WIRE.md and docs/OPERATIONS.md are the
//! operator-facing contract for the measurement and tune wires. Field
//! names and error texts there must track `proto.rs`/`tune_proto.rs`
//! exactly, in both directions:
//!
//! 1. every wire field the codecs read or write appears in WIRE.md;
//! 2. every field documented in a WIRE.md table exists in the codecs;
//! 3. every error text in the OPERATIONS.md failure-mode table (and the
//!    WIRE.md error sections) matches a literal in `rust/src/eval`;
//! 4. every error *reply* the daemons construct is documented.
//!
//! Error texts are compared as *skeletons*: each `{...}` placeholder —
//! on either side — becomes a wildcard, a doc text ending in `...`
//! matches by prefix, and a code literal may continue past the
//! documented text at a newline (multi-line refusals document their
//! first line).

use super::model::SourceFile;
use super::Finding;

pub const RULE: &str = "wire-docs";

const PROTO_FILES: &[&str] = &["rust/src/eval/proto.rs", "rust/src/eval/tune_proto.rs"];
const ERROR_REPLY_FILES: &[&str] =
    &["rust/src/eval/server.rs", "rust/src/eval/tune_server.rs"];

/// Lower-snake-case identifier — the shape of a wire field name.
fn is_field_ident(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_lowercase() => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

/// Replace every balanced `{...}` region (either side's placeholder
/// syntax) with a single NUL wildcard, innermost first.
fn skeleton(s: &str) -> String {
    let mut cur: Vec<char> = s.chars().collect();
    loop {
        let mut out: Vec<char> = Vec::with_capacity(cur.len());
        let mut changed = false;
        let mut i = 0;
        while i < cur.len() {
            if cur[i] == '{' {
                let mut j = i + 1;
                let mut simple = true;
                while j < cur.len() && cur[j] != '}' {
                    if cur[j] == '{' {
                        simple = false;
                        break;
                    }
                    j += 1;
                }
                if simple && j < cur.len() {
                    out.push('\u{0}');
                    i = j + 1;
                    changed = true;
                    continue;
                }
            }
            out.push(cur[i]);
            i += 1;
        }
        cur = out;
        if !changed {
            break;
        }
    }
    cur.into_iter().collect()
}

/// Match a doc skeleton against a code skeleton: wildcard segments must
/// appear in order; `full` additionally anchors the tail at the end.
fn wildcard_match(doc: &str, code: &str, full: bool) -> bool {
    let segs: Vec<&str> = doc.split('\u{0}').collect();
    let mut pos = 0usize;
    for (si, seg) in segs.iter().enumerate() {
        if si == 0 {
            if !code.starts_with(seg) {
                return false;
            }
            pos = seg.len();
        } else {
            match code[pos..].find(seg) {
                Some(at) => pos = pos + at + seg.len(),
                None => return false,
            }
        }
    }
    if full {
        let last = segs.last().copied().unwrap_or("");
        if last.is_empty() {
            return true;
        }
        code.ends_with(last) && pos == code.len()
    } else {
        true
    }
}

/// Does documented error text `doc` describe code literal `code`?
pub fn skel_match(doc: &str, code: &str) -> bool {
    let d = skeleton(doc);
    let c = skeleton(code);
    if let Some(prefix) = d.strip_suffix("...") {
        return wildcard_match(prefix, &c, false);
    }
    wildcard_match(&d, &c, true) || wildcard_match(&format!("{d}\n"), &c, false)
}

/// All `` `span` `` backtick spans in a line, with byte-free simplicity.
fn backtick_spans(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = line;
    while let Some(open) = rest.find('`') {
        let tail = &rest[open + 1..];
        let Some(close) = tail.find('`') else { break };
        out.push(tail[..close].to_string());
        rest = &tail[close + 1..];
    }
    out
}

/// Wire field names the codecs read or write, with their location:
/// `key("x")` / `get("x")` writers-readers, `("x", ...)` object-builder
/// tuples, and `"x" =>` / `=> "x"` match arms.
fn code_fields(files: &[&SourceFile]) -> Vec<(String, String, usize)> {
    let mut out = Vec::new();
    for f in files {
        let toks = &f.tokens;
        for i in 0..toks.len() {
            if f.excluded[i] {
                continue;
            }
            let Some(s) = toks[i].str_lit() else { continue };
            if !is_field_ident(s) {
                continue;
            }
            let prev = i.checked_sub(1).map(|j| &toks[j]);
            let prev2 = i.checked_sub(2).map(|j| &toks[j]);
            let nxt = toks.get(i + 1);
            let nxt2 = toks.get(i + 2);
            let after_accessor = prev.is_some_and(|t| t.is_punct('('))
                && prev2
                    .and_then(|t| t.ident())
                    .is_some_and(|n| n == "key" || n == "get");
            let tuple_head = prev.is_some_and(|t| t.is_punct('('))
                && nxt.is_some_and(|t| t.is_punct(','));
            let arm_lhs = nxt.is_some_and(|t| t.is_punct('='))
                && nxt2.is_some_and(|t| t.is_punct('>'));
            let arm_rhs = prev.is_some_and(|t| t.is_punct('>'))
                && prev2.is_some_and(|t| t.is_punct('='));
            if after_accessor || tuple_head || arm_lhs || arm_rhs {
                out.push((s.to_string(), f.path.clone(), toks[i].line));
            }
        }
    }
    out
}

/// Backticked identifiers in the first column of WIRE.md tables — the
/// documented field names.
fn doc_field_idents(wire_md: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    for (idx, line) in wire_md.lines().enumerate() {
        let ls = line.trim();
        if !ls.starts_with('|') {
            continue;
        }
        let Some(col1) = ls.split('|').nth(1) else { continue };
        for span in backtick_spans(col1) {
            if is_field_ident(&span) {
                out.push((span, idx + 1));
            }
        }
    }
    out
}

/// Documented error texts: OPERATIONS.md "Failure modes" table column 1
/// plus backticked spans in WIRE.md sections whose heading mentions
/// errors. Spans without a space are field names, not error texts.
fn doc_error_texts(ops_md: &str, wire_md: &str) -> Vec<(String, String, usize)> {
    let mut out = Vec::new();
    let mut in_table = false;
    for (idx, line) in ops_md.lines().enumerate() {
        let ls = line.trim();
        if let Some(h) = ls.strip_prefix("## ") {
            in_table = h.to_ascii_lowercase().starts_with("failure");
            continue;
        }
        if in_table && ls.starts_with('|') {
            if let Some(col1) = ls.split('|').nth(1) {
                for span in backtick_spans(col1) {
                    if span.contains(' ') {
                        out.push((span, "docs/OPERATIONS.md".to_string(), idx + 1));
                    }
                }
            }
        }
    }
    let mut in_err = false;
    for (idx, line) in wire_md.lines().enumerate() {
        let ls = line.trim();
        if ls.starts_with('#') {
            in_err = ls.to_ascii_lowercase().contains("error");
            continue;
        }
        if in_err {
            for span in backtick_spans(line) {
                if span.contains(' ') {
                    out.push((span, "docs/WIRE.md".to_string(), idx + 1));
                }
            }
        }
    }
    out
}

/// String literals the daemons put in `Error(...)` replies — directly
/// or via `Error(format!("..."))`.
fn error_reply_literals(files: &[&SourceFile]) -> Vec<(String, String, usize)> {
    let mut out = Vec::new();
    for f in files {
        if !ERROR_REPLY_FILES.contains(&f.path.as_str()) {
            continue;
        }
        let toks = &f.tokens;
        for i in 0..toks.len() {
            if f.excluded[i] || !toks[i].is_ident("Error") {
                continue;
            }
            if !toks.get(i + 1).is_some_and(|t| t.is_punct('(')) {
                continue;
            }
            if let Some(s) = toks.get(i + 2).and_then(|t| t.str_lit()) {
                out.push((s.to_string(), f.path.clone(), toks[i + 2].line));
            } else if toks.get(i + 2).is_some_and(|t| t.is_ident("format"))
                && toks.get(i + 3).is_some_and(|t| t.is_punct('!'))
                && toks.get(i + 4).is_some_and(|t| t.is_punct('('))
            {
                if let Some(s) = toks.get(i + 5).and_then(|t| t.str_lit()) {
                    out.push((s.to_string(), f.path.clone(), toks[i + 5].line));
                }
            }
        }
    }
    out
}

/// Run the whole bidirectional sync check. `eval_files` is every lexed
/// file under `rust/src/eval/`.
pub fn check(eval_files: &[&SourceFile], wire_md: &str, ops_md: &str) -> Vec<Finding> {
    let mut out = Vec::new();
    let proto: Vec<&SourceFile> = eval_files
        .iter()
        .copied()
        .filter(|f| PROTO_FILES.contains(&f.path.as_str()))
        .collect();

    // 1. code fields -> WIRE.md
    for (name, path, line) in code_fields(&proto) {
        let documented =
            wire_md.contains(&format!("`{name}`")) || wire_md.contains(&format!("\"{name}\""));
        if !documented {
            out.push(Finding {
                rule: RULE,
                file: path,
                line,
                message: format!("wire field \"{name}\" is not documented in docs/WIRE.md"),
            });
        }
    }

    // 2. WIRE.md table fields -> code
    let mut code_strs: Vec<&str> = Vec::new();
    for f in &proto {
        for (i, t) in f.tokens.iter().enumerate() {
            if !f.excluded[i] {
                if let Some(s) = t.str_lit() {
                    code_strs.push(s);
                }
            }
        }
    }
    for (name, line) in doc_field_idents(wire_md) {
        if !code_strs.contains(&name.as_str()) {
            out.push(Finding {
                rule: RULE,
                file: "docs/WIRE.md".to_string(),
                line,
                message: format!(
                    "documented field `{name}` does not exist in proto.rs/tune_proto.rs"
                ),
            });
        }
    }

    // 3. documented error texts -> some literal in rust/src/eval
    let mut pool: Vec<&str> = Vec::new();
    for f in eval_files {
        for (i, t) in f.tokens.iter().enumerate() {
            if !f.excluded[i] {
                if let Some(s) = t.str_lit() {
                    pool.push(s);
                }
            }
        }
    }
    let doc_errors = doc_error_texts(ops_md, wire_md);
    for (txt, dfile, line) in &doc_errors {
        if !pool.iter().any(|c| skel_match(txt, c)) {
            out.push(Finding {
                rule: RULE,
                file: dfile.clone(),
                line: *line,
                message: format!(
                    "documented error text `{txt}` matches no literal in rust/src/eval \
                     — stale docs or changed wording"
                ),
            });
        }
    }

    // 4. daemon Error(...) replies -> documented somewhere
    for (lit, path, line) in error_reply_literals(eval_files) {
        if !doc_errors.iter().any(|(d, _, _)| skel_match(d, &lit)) {
            out.push(Finding {
                rule: RULE,
                file: path,
                line,
                message: format!(
                    "error reply \"{lit}\" is not documented in the OPERATIONS.md \
                     failure-mode table or a WIRE.md error section"
                ),
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skeletons_wildcard_placeholders_on_both_sides() {
        assert!(skel_match(
            "client {c} speaks tune-protocol v{n}, this daemon v{v}",
            "client {client} speaks tune-protocol v{proto}, this daemon v{TUNE_PROTO_VERSION}"
        ));
        assert!(!skel_match(
            "client {c} speaks tune-protocol v{n}, this daemon v1",
            "client {client} speaks tune-protocol v{proto}, this daemon v{TUNE_PROTO_VERSION}"
        ));
    }

    #[test]
    fn doc_ellipsis_matches_by_prefix() {
        assert!(skel_match(
            "journal {path} is in the v1 whole-file JSON format, ...",
            "journal {} is in the v1 whole-file JSON format, which has no fingerprint"
        ));
    }

    #[test]
    fn code_may_continue_past_a_newline() {
        assert!(skel_match(
            "shard {addr} embeds a different simulator — refusing to mix numbers.",
            "shard {addr} embeds a different simulator — refusing to mix numbers.\n  shard: {a}\n  binary: {b}"
        ));
    }

    #[test]
    fn undocumented_field_is_flagged_both_ways() {
        let proto = SourceFile::parse(
            "rust/src/eval/proto.rs".to_string(),
            r#"fn enc() { w.key("task"); w.key("mystery"); }"#,
        );
        let wire = "| `task` | the task | yes |\n| `ghost` | gone | no |";
        let fs = check(&[&proto], wire, "");
        let msgs: Vec<&str> = fs.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("\"mystery\"")));
        assert!(msgs.iter().any(|m| m.contains("`ghost`")));
        assert!(!msgs.iter().any(|m| m.contains("task")));
    }
}
