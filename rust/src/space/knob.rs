//! Knob and configuration-space machinery.

use crate::util::json::Json;
use crate::util::stats::divisors;
use crate::vta::VtaConfig;
use crate::workload::Conv2dTask;

/// Which agent owns a knob (Table 1/2 partition).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum KnobOwner {
    Hardware,
    Scheduling,
    Mapping,
}

/// One tunable dimension: a name and its discrete candidate values.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Knob {
    pub name: &'static str,
    pub owner: KnobOwner,
    pub values: Vec<usize>,
}

impl Knob {
    pub fn len(&self) -> usize {
        self.values.len()
    }
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// The software half of a decoded configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SwConfig {
    /// Output rows per spatial tile.
    pub tile_h: usize,
    /// Output cols per spatial tile.
    pub tile_w: usize,
    /// Virtual threads across the height dimension (1 or 2).
    pub h_threading: usize,
    /// Virtual threads across output channels (1 or 2).
    pub oc_threading: usize,
}

/// A point in the space: one value index per knob, in space order.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PointConfig(pub Vec<usize>);

impl PointConfig {
    pub fn as_slice(&self) -> &[usize] {
        &self.0
    }
}

/// The per-task configuration space.
#[derive(Debug, Clone)]
pub struct ConfigSpace {
    pub task: Conv2dTask,
    pub knobs: Vec<Knob>,
    /// When false, hardware knobs are present but frozen to index of the
    /// VTA++ default value (software-only frameworks).
    pub hardware_tunable: bool,
}

/// Pick at most `max` values from a sorted candidate list, always keeping
/// the first and last, spreading the rest evenly.
fn thin(values: Vec<usize>, max: usize) -> Vec<usize> {
    if values.len() <= max {
        return values;
    }
    let n = values.len();
    let mut out = Vec::with_capacity(max);
    for i in 0..max {
        let idx = i * (n - 1) / (max - 1);
        if out.last() != Some(&values[idx]) {
            out.push(values[idx]);
        }
    }
    out
}

/// Spatial tile candidates for an output dimension: divisors, thinned to 8.
fn tile_candidates(dim: usize) -> Vec<usize> {
    thin(divisors(dim), 8)
}

impl ConfigSpace {
    /// Build the Table-2 space for a task. `hardware_tunable=false` freezes
    /// the GEMM geometry at the VTA++ default (AutoTVM/CHAMELEON mode).
    pub fn for_task(task: &Conv2dTask, hardware_tunable: bool) -> ConfigSpace {
        let knobs = vec![
            Knob { name: "tile_b", owner: KnobOwner::Hardware, values: vec![1, 2, 4, 8] },
            Knob { name: "tile_ci", owner: KnobOwner::Hardware, values: vec![8, 16, 32, 64] },
            Knob { name: "tile_co", owner: KnobOwner::Hardware, values: vec![8, 16, 32, 64] },
            Knob { name: "h_threading", owner: KnobOwner::Scheduling, values: vec![1, 2] },
            Knob { name: "oc_threading", owner: KnobOwner::Scheduling, values: vec![1, 2] },
            Knob { name: "tile_h", owner: KnobOwner::Mapping, values: tile_candidates(task.oh()) },
            Knob { name: "tile_w", owner: KnobOwner::Mapping, values: tile_candidates(task.ow()) },
        ];
        ConfigSpace { task: *task, knobs, hardware_tunable }
    }

    /// Number of knobs (always 7).
    pub fn num_knobs(&self) -> usize {
        self.knobs.len()
    }

    /// Index of a knob by name.
    pub fn knob_index(&self, name: &str) -> Option<usize> {
        self.knobs.iter().position(|k| k.name == name)
    }

    /// Indices of the knobs a given agent owns.
    pub fn agent_knobs(&self, owner: KnobOwner) -> Vec<usize> {
        self.knobs
            .iter()
            .enumerate()
            .filter(|(_, k)| k.owner == owner)
            .map(|(i, _)| i)
            .collect()
    }

    /// Is knob `i` frozen in this space — present, but pinned to the
    /// default value? True exactly for hardware knobs of a software-only
    /// (hardware-frozen) space. The single predicate every sampler,
    /// neighbourhood and synthesis path must consult before moving a knob.
    pub fn knob_frozen(&self, i: usize) -> bool {
        !self.hardware_tunable && self.knobs[i].owner == KnobOwner::Hardware
    }

    /// Total number of points (tunable dimensions only).
    pub fn size(&self) -> usize {
        self.knobs
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.knob_frozen(*i))
            .map(|(_, k)| k.len())
            .product()
    }

    /// The index vector of the hardware-default / minimal-software point.
    pub fn default_point(&self) -> PointConfig {
        let hw = VtaConfig::default();
        let idx = self
            .knobs
            .iter()
            .map(|k| match k.name {
                "tile_b" => position_of(&k.values, hw.batch),
                "tile_ci" => position_of(&k.values, hw.block_in),
                "tile_co" => position_of(&k.values, hw.block_out),
                "h_threading" | "oc_threading" => 0,
                // Mid-size spatial tiles as the neutral start.
                _ => k.len() / 2,
            })
            .collect();
        PointConfig(idx)
    }

    /// Uniform-random point (respects frozen hardware knobs).
    pub fn random_point(&self, rng: &mut crate::util::rng::Pcg32) -> PointConfig {
        let default = self.default_point();
        let idx = self
            .knobs
            .iter()
            .enumerate()
            .map(|(i, k)| {
                if self.knob_frozen(i) {
                    default.0[i]
                } else {
                    rng.gen_range(k.len())
                }
            })
            .collect();
        PointConfig(idx)
    }

    /// Flat linear index of a point (row-major over knob value indices).
    pub fn flat_index(&self, p: &PointConfig) -> usize {
        let mut idx = 0usize;
        for (k, &v) in self.knobs.iter().zip(&p.0) {
            idx = idx * k.len() + v;
        }
        idx
    }

    /// Inverse of [`flat_index`].
    pub fn from_flat_index(&self, mut idx: usize) -> PointConfig {
        let mut out = vec![0usize; self.knobs.len()];
        for (i, k) in self.knobs.iter().enumerate().rev() {
            out[i] = idx % k.len();
            idx /= k.len();
        }
        PointConfig(out)
    }

    /// Validate a point's index vector against knob arities.
    pub fn contains(&self, p: &PointConfig) -> bool {
        p.0.len() == self.knobs.len()
            && p.0.iter().zip(&self.knobs).all(|(&v, k)| v < k.len())
    }

    /// Decode a point into concrete hardware + software configs.
    pub fn decode(&self, p: &PointConfig) -> (VtaConfig, SwConfig) {
        assert!(self.contains(p), "point {:?} outside space", p);
        let v = |name: &str| -> usize {
            let i = self.knob_index(name).unwrap();
            self.knobs[i].values[p.0[i]]
        };
        let hw = VtaConfig::with_gemm(v("tile_b"), v("tile_ci"), v("tile_co"));
        let sw = SwConfig {
            tile_h: v("tile_h"),
            tile_w: v("tile_w"),
            h_threading: v("h_threading"),
            oc_threading: v("oc_threading"),
        };
        (hw, sw)
    }

    /// Neighbours of a point: one knob stepped ±1 (the RL action space and
    /// the simulated-annealing move set).
    pub fn neighbours(&self, p: &PointConfig) -> Vec<PointConfig> {
        let mut out = Vec::new();
        for (i, k) in self.knobs.iter().enumerate() {
            if self.knob_frozen(i) {
                continue;
            }
            if p.0[i] > 0 {
                let mut q = p.clone();
                q.0[i] -= 1;
                out.push(q);
            }
            if p.0[i] + 1 < k.len() {
                let mut q = p.clone();
                q.0[i] += 1;
                out.push(q);
            }
        }
        out
    }

    /// Normalized feature vector of a point in [0,1]^num_knobs (for cost
    /// models and RL observations).
    pub fn normalized(&self, p: &PointConfig) -> Vec<f64> {
        self.knobs
            .iter()
            .zip(&p.0)
            .map(|(k, &v)| if k.len() <= 1 { 0.0 } else { v as f64 / (k.len() - 1) as f64 })
            .collect()
    }

    /// Human-readable rendering: `tile_b=1 tile_ci=16 ...`.
    pub fn render(&self, p: &PointConfig) -> String {
        self.knobs
            .iter()
            .zip(&p.0)
            .map(|(k, &v)| format!("{}={}", k.name, k.values[v]))
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn point_to_json(&self, p: &PointConfig) -> Json {
        Json::Obj(
            self.knobs
                .iter()
                .zip(&p.0)
                .map(|(k, &v)| (k.name.to_string(), Json::num(k.values[v] as f64)))
                .collect(),
        )
    }
}

fn position_of(values: &[usize], v: usize) -> usize {
    values.iter().position(|&x| x == v).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop_assert;
    use crate::util::prop::check;
    use crate::util::rng::Pcg32;

    fn task() -> Conv2dTask {
        Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1)
    }

    #[test]
    fn seven_knobs_partitioned_as_table2() {
        let s = ConfigSpace::for_task(&task(), true);
        assert_eq!(s.num_knobs(), 7);
        assert_eq!(s.agent_knobs(KnobOwner::Hardware).len(), 3);
        assert_eq!(s.agent_knobs(KnobOwner::Scheduling).len(), 2);
        assert_eq!(s.agent_knobs(KnobOwner::Mapping).len(), 2);
    }

    #[test]
    fn space_size_order_matches_paper() {
        // Paper: O(2^12). Our space: 4*4*4*2*2*|th|*|tw|.
        let s = ConfigSpace::for_task(&task(), true);
        let size = s.size();
        assert!(size >= 1 << 10 && size <= 1 << 15, "size {size}");
    }

    #[test]
    fn knob_frozen_marks_exactly_the_hardware_knobs_of_a_frozen_space() {
        let full = ConfigSpace::for_task(&task(), true);
        let frozen = ConfigSpace::for_task(&task(), false);
        for i in 0..full.num_knobs() {
            assert!(!full.knob_frozen(i), "nothing is frozen in a co-design space");
            assert_eq!(
                frozen.knob_frozen(i),
                frozen.knobs[i].owner == KnobOwner::Hardware,
                "knob {i}"
            );
        }
    }

    #[test]
    fn frozen_hardware_shrinks_space() {
        let full = ConfigSpace::for_task(&task(), true);
        let sw = ConfigSpace::for_task(&task(), false);
        assert_eq!(full.size(), sw.size() * 4 * 4 * 4);
    }

    #[test]
    fn default_point_decodes_to_vta_default() {
        let s = ConfigSpace::for_task(&task(), true);
        let (hw, _) = s.decode(&s.default_point());
        assert_eq!((hw.batch, hw.block_in, hw.block_out), (1, 16, 16));
    }

    #[test]
    fn flat_index_roundtrip() {
        let s = ConfigSpace::for_task(&task(), true);
        let mut rng = Pcg32::seeded(4);
        for _ in 0..200 {
            let p = s.random_point(&mut rng);
            let idx = s.flat_index(&p);
            assert_eq!(s.from_flat_index(idx), p);
        }
    }

    #[test]
    fn frozen_random_points_keep_default_hw() {
        let s = ConfigSpace::for_task(&task(), false);
        let mut rng = Pcg32::seeded(9);
        for _ in 0..50 {
            let p = s.random_point(&mut rng);
            let (hw, _) = s.decode(&p);
            assert_eq!((hw.batch, hw.block_in, hw.block_out), (1, 16, 16));
        }
    }

    #[test]
    fn neighbours_step_one_knob() {
        let s = ConfigSpace::for_task(&task(), true);
        let p = s.default_point();
        for q in s.neighbours(&p) {
            let diff: usize = p
                .0
                .iter()
                .zip(&q.0)
                .map(|(&a, &b)| (a as i64 - b as i64).unsigned_abs() as usize)
                .sum();
            assert_eq!(diff, 1);
            assert!(s.contains(&q));
        }
    }

    #[test]
    fn frozen_space_has_no_hw_neighbours() {
        let s = ConfigSpace::for_task(&task(), false);
        let p = s.default_point();
        for q in s.neighbours(&p) {
            let (hw, _) = s.decode(&q);
            assert_eq!((hw.batch, hw.block_in, hw.block_out), (1, 16, 16));
        }
    }

    #[test]
    fn tile_candidates_cover_extremes() {
        let c = tile_candidates(112);
        assert_eq!(*c.first().unwrap(), 1);
        assert_eq!(*c.last().unwrap(), 112);
        assert!(c.len() <= 8);
        assert!(c.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn normalized_in_unit_box() {
        let s = ConfigSpace::for_task(&task(), true);
        let s2 = s.clone();
        check(
            "normalized-unit-box",
            0xA5,
            100,
            move |r| s2.random_point(r),
            |p| {
                for f in s.normalized(p) {
                    prop_assert!((0.0..=1.0).contains(&f), "feature {f} out of [0,1]");
                }
                Ok(())
            },
        );
    }

    #[test]
    fn render_mentions_all_knobs() {
        let s = ConfigSpace::for_task(&task(), true);
        let txt = s.render(&s.default_point());
        for k in &s.knobs {
            assert!(txt.contains(k.name), "{txt}");
        }
    }
}
