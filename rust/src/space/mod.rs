//! The co-optimization design space (Table 2).
//!
//! Seven knobs per convolution task, partitioned across the three MARL
//! agents exactly as the paper assigns them:
//!
//! | Agent                  | Knobs                      |
//! |------------------------|----------------------------|
//! | Hardware agent         | `tile_b`, `tile_ci`, `tile_co` (the VTA++ GEMM geometry: BATCH, BLOCK_IN, BLOCK_OUT) |
//! | Scheduling agent (sw)  | `h_threading`, `oc_threading` (virtual-thread parallelism) |
//! | Mapping agent (sw)     | `tile_h`, `tile_w` (spatial data distribution) |
//!
//! The full space is O(2^12) configurations per task, matching the paper.
//! Software-only baselines (AutoTVM, CHAMELEON) get the same space with the
//! hardware knobs frozen at the VTA++ default (§4.1).

pub mod knob;

pub use knob::{ConfigSpace, Knob, KnobOwner, PointConfig, SwConfig};
