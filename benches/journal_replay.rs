//! Journal replay at scale: the warm-start hot path.
//!
//! A fleet shard replays its journal on every start; the tuner's journal
//! merge/compact tools walk the same lines. This bench measures one full
//! pass over a million-record journal (50k in `ARCO_BENCH_QUICK=1` mode)
//! three ways:
//!
//!  - `tree_full_decode`   — the legacy path: `Json::parse` builds a tree
//!    per line, then `record_from_json` walks it;
//!  - `stream_full_decode` — the zero-copy streaming decoder;
//!  - `stream_identity_only` — lazy extraction of just `(backend, task,
//!    values)`, skipping the payload subtree (what merge dedup and compact
//!    GC actually need);
//!
//! plus `open_read_only`, the end-to-end `Journal` replay (I/O, UTF-8
//! checks, dedup set) on the same corpus written to a real file. The
//! speedup of streaming over tree is printed at the end — the acceptance
//! gate for the codec is >=3x on the full decode.

use arco::eval::proto::{
    record_from_json, record_from_line, record_identity_from_line, write_record_line,
};
use arco::eval::{Fingerprint, Journal, MeasureResult, PointKey};
use arco::space::ConfigSpace;
use arco::util::bench::{black_box, BenchRunner};
use arco::util::json::Json;
use arco::util::rng::Pcg32;
use arco::workload::Conv2dTask;

fn main() {
    arco::util::log::init_from_env();
    let quick = std::env::var("ARCO_BENCH_QUICK").is_ok_and(|v| v == "1");
    let n: usize = if quick { 50_000 } else { 1_000_000 };
    let mut runner = BenchRunner::new("journal_replay");

    // Corpus: n record lines over a realistic tuning space. Identities
    // cycle through a 4096-point pool (so the journal's dedup set stays
    // small and the bench measures parsing, not allocator churn), while
    // payloads vary per line so no two lines are byte-equal.
    let space = ConfigSpace::for_task(&Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1), true);
    let mut rng = Pcg32::seeded(9);
    let keys: Vec<PointKey> =
        (0..4096).map(|_| PointKey::of(&space, &space.random_point(&mut rng))).collect();
    let mut corpus = String::with_capacity(n * 280);
    let mut buf = Vec::with_capacity(512);
    for i in 0..n {
        let key = &keys[i % keys.len()];
        let valid = i % 16 != 0;
        let result = MeasureResult {
            seconds: if valid { 1e-9 * (i as f64 + 1.0) } else { f64::INFINITY },
            cycles: if valid { (i as u64).wrapping_mul(0x9E37_79B9) } else { 0 },
            gflops: (i % 97) as f64 * 0.5,
            area_mm2: 3.25,
            occupancy: (i % 100) as f64 / 100.0,
            valid,
        };
        let backend = if i % 2 == 0 { "vta-sim" } else { "analytical" };
        buf.clear();
        write_record_line(&mut buf, backend, key, &result).unwrap();
        corpus.push_str(std::str::from_utf8(&buf).unwrap());
    }
    println!("corpus: {n} record lines, {:.1} MB", corpus.len() as f64 / 1e6);
    let elems = Some(n as u64);

    runner.bench_with_elements("replay/tree_full_decode", elems, || {
        let mut ok = 0usize;
        for line in corpus.lines() {
            if let Some(r) = Json::parse(line).ok().and_then(|v| record_from_json(&v)) {
                black_box(&r);
                ok += 1;
            }
        }
        assert_eq!(black_box(ok), n);
    });
    runner.bench_with_elements("replay/stream_full_decode", elems, || {
        let mut ok = 0usize;
        for line in corpus.lines() {
            if let Some(r) = record_from_line(line) {
                black_box(&r);
                ok += 1;
            }
        }
        assert_eq!(black_box(ok), n);
    });
    runner.bench_with_elements("replay/stream_identity_only", elems, || {
        let mut ok = 0usize;
        for line in corpus.lines() {
            if let Some(r) = record_identity_from_line(line) {
                black_box(&r);
                ok += 1;
            }
        }
        assert_eq!(black_box(ok), n);
    });

    // End-to-end replay: header check, buffered I/O, per-line UTF-8
    // validation, dedup set — everything a shard pays on warm start.
    let path =
        std::env::temp_dir().join(format!("arco_bench_journal_{}.jsonl", std::process::id()));
    let header = Json::obj(vec![
        ("format", Json::str("arco-journal")),
        ("version", Json::num(Journal::VERSION as f64)),
        ("fingerprint", Fingerprint::current().to_json()),
    ]);
    std::fs::write(&path, format!("{}\n{corpus}", header.dump())).unwrap();
    runner.bench_with_elements("replay/journal_open_read_only", elems, || {
        let j = Journal::open_read_only(&path).unwrap();
        assert_eq!(black_box(j.len()), keys.len().min(n));
    });
    let _ = std::fs::remove_file(&path);

    let results = runner.finish();
    let mean = |name: &str| {
        results.iter().find(|r| r.name == name).map(|r| r.mean_ns).unwrap_or(f64::NAN)
    };
    let tree = mean("replay/tree_full_decode");
    println!(
        "speedup over tree parse: full decode {:.2}x, identity-only {:.2}x",
        tree / mean("replay/stream_full_decode"),
        tree / mean("replay/stream_identity_only"),
    );
}
