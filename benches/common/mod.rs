#![allow(dead_code)] // shared across bench targets; each uses a subset
//! Shared helpers for the paper-figure benches.
//!
//! Every bench honours two environment knobs so the full suite can run at
//! CI scale or paper scale:
//!   - `ARCO_BENCH_TRIALS`   measurements per task (default 192)
//!   - `ARCO_BENCH_MODELS`   comma list or "all" (default a 3-model subset)

use arco::tuner::TuneBudget;

pub fn trials() -> usize {
    std::env::var("ARCO_BENCH_TRIALS").ok().and_then(|v| v.parse().ok()).unwrap_or(192)
}

pub fn budget() -> TuneBudget {
    TuneBudget { total_measurements: trials(), batch: 64, ..Default::default() }
}

pub fn models() -> Vec<String> {
    let spec = std::env::var("ARCO_BENCH_MODELS").unwrap_or_else(|_| "alexnet,resnet18,vgg11".into());
    if spec == "all" {
        arco::workload::model_names().iter().map(|s| s.to_string()).collect()
    } else {
        spec.split(',').map(|s| s.trim().to_string()).collect()
    }
}

pub fn seed() -> u64 {
    20260710
}

use arco::tuner::{compare_frameworks, CompareReport, Framework};
use arco::workload::model_by_name;

/// Run the paper's three-framework comparison over the bench model set.
/// Shared by the table6/fig5/fig6/fig7 bench targets.
pub fn run_paper_comparison() -> Vec<CompareReport> {
    let budget = budget();
    let mut reports = Vec::new();
    for name in models() {
        let model = model_by_name(&name).unwrap_or_else(|| panic!("unknown model {name}"));
        eprintln!(
            "[bench] comparing on {name} ({} unique tasks, {} trials/task)",
            model.unique_tasks().len(),
            trials()
        );
        reports.push(
            compare_frameworks(&Framework::paper_set(), &model, budget, true, seed())
                .expect("measurement backend lost"),
        );
    }
    reports
}
