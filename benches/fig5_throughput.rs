//! Bench: regenerate Fig. 5 — throughput of each framework normalized to
//! AutoTVM (paper: ARCO averages 1.17x, up to +37.95%).

mod common;

use arco::report;
use arco::tuner::Framework;

fn main() {
    arco::util::log::init_from_env();
    let reports = common::run_paper_comparison();
    let csv = report::fig5_throughput(&reports);
    let summary = report::fig5_summary(&reports);
    println!("\n{csv}\n{summary}");
    report::write_result("fig5_throughput.csv", &csv).unwrap();
    report::write_result("fig5_summary.txt", &summary).unwrap();

    for r in &reports {
        let rel = r.throughput_vs_autotvm(Framework::Arco).unwrap();
        assert!(rel >= 0.95, "{}: ARCO relative throughput {rel} < 1", r.model);
        println!("{}: ARCO {rel:.3}x vs AutoTVM", r.model);
    }
}
