//! Ablation benches for the design choices DESIGN.md calls out:
//!  - hardware co-design gain: ARCO vs ARCO with frozen hardware knobs;
//!  - MARL vs single-agent RL (CHAMELEON's explorer) on the same space;
//!  - Confidence Sampling vs surrogate top-k (fig4 bench covers the
//!    measurement-count side; this one compares final quality).

mod common;

use arco::tuner::{tune_model, Framework};
use arco::workload::model_by_name;

fn main() {
    arco::util::log::init_from_env();
    let model = model_by_name("resnet18").unwrap();
    let budget = common::budget();
    let seed = common::seed();

    let full = tune_model(Framework::Arco, &model, budget, true, seed).unwrap();
    let sw_only = tune_model(Framework::ArcoSwOnly, &model, budget, true, seed).unwrap();
    let no_cs = tune_model(Framework::ArcoNoCs, &model, budget, true, seed).unwrap();
    let chameleon = tune_model(Framework::Chameleon, &model, budget, true, seed).unwrap();
    let random = tune_model(Framework::Random, &model, budget, true, seed).unwrap();

    println!("\nablation results on resnet18 (mean inference secs; lower is better):");
    let rows = [
        ("arco (full)", &full),
        ("arco w/o hardware knobs", &sw_only),
        ("arco w/o confidence sampling", &no_cs),
        ("single-agent RL (chameleon)", &chameleon),
        ("random search", &random),
    ];
    for (name, o) in rows {
        println!(
            "  {name:<30} {:.5} s   ({} measurements, {:.1}s modeled compile)",
            o.inference_secs, o.measurements, o.compile_secs
        );
    }

    // Co-design gain: hardware knobs must matter.
    assert!(
        full.inference_secs < sw_only.inference_secs,
        "hardware co-design should improve over software-only"
    );
    // MARL on the *co-design* space should beat single-agent RL on the
    // software-only space (the paper's core claim).
    assert!(
        full.inference_secs < chameleon.inference_secs,
        "ARCO should beat CHAMELEON"
    );
    println!("\nshape OK: co-design gain and MARL advantage both present");
}
