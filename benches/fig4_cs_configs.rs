//! Bench: regenerate Fig. 4 — configurations measured over time for
//! ResNet-18, before vs after applying Confidence Sampling.
//!
//! Both variants run to the same measurement budget (the tuner exhausts
//! whatever it is given), so the CS effect shows up as (a) fewer
//! configurations measured *per planning iteration* and (b) fewer
//! measurements needed to reach the same code quality — exactly the
//! "sampling gravitates towards configurations that demonstrate superior
//! performance over time" reading of the paper's figure.

mod common;

use arco::report;
use arco::tuner::{tune_model, Framework, ModelOutcome};
use arco::workload::model_by_name;

/// Mean measurements per planning iteration across a model's tasks.
fn per_iteration(o: &ModelOutcome) -> f64 {
    let mut total_meas = 0usize;
    let mut total_iters = 0usize;
    for t in &o.tasks {
        total_meas += t.result.trace.len();
        total_iters += t.result.trace.iter().map(|e| e.iteration).max().map_or(0, |i| i + 1);
    }
    total_meas as f64 / total_iters.max(1) as f64
}

/// Measurements needed (heaviest task) to reach `frac` of a target GFLOPS.
fn measurements_to(o: &ModelOutcome, target: f64, frac: f64) -> usize {
    let t = o
        .tasks
        .iter()
        .max_by_key(|t| t.result.trace.len())
        .expect("tasks");
    for e in &t.result.trace {
        if e.best_gflops >= target * frac {
            return e.ordinal;
        }
    }
    t.result.trace.len()
}

fn main() {
    arco::util::log::init_from_env();
    let model = model_by_name("resnet18").unwrap();
    let budget = common::budget();

    let with_cs = tune_model(Framework::Arco, &model, budget, true, common::seed()).unwrap();
    let without_cs = tune_model(Framework::ArcoNoCs, &model, budget, true, common::seed()).unwrap();

    let pick = |o: &ModelOutcome| {
        o.tasks
            .iter()
            .max_by_key(|t| t.result.trace.len())
            .map(|t| t.result.trace.clone())
            .unwrap_or_default()
    };
    let csv = report::fig4_configs_over_time(
        "after_cs",
        &pick(&with_cs),
        "before_cs",
        &pick(&without_cs),
    );
    report::write_result("fig4_cs_resnet18.csv", &csv).unwrap();

    let cs_rate = per_iteration(&with_cs);
    let nocs_rate = per_iteration(&without_cs);
    println!(
        "with CS:    {:.1} configs/iteration, {} total, {:.5}s final inference",
        cs_rate, with_cs.measurements, with_cs.inference_secs
    );
    println!(
        "without CS: {:.1} configs/iteration, {} total, {:.5}s final inference",
        nocs_rate, without_cs.measurements, without_cs.inference_secs
    );

    // Measurements to reach 95% of the no-CS variant's final quality.
    let target = without_cs
        .tasks
        .iter()
        .max_by_key(|t| t.result.trace.len())
        .map(|t| t.result.best.gflops)
        .unwrap_or(0.0);
    let m_cs = measurements_to(&with_cs, target, 0.95);
    let m_nocs = measurements_to(&without_cs, target, 0.95);
    println!("measurements to 95% quality: with CS {m_cs}, without {m_nocs}");

    // Fig 4's claims: CS measures fewer configs per iteration and loses no
    // meaningful final quality.
    assert!(
        cs_rate < nocs_rate * 0.95,
        "CS should measure fewer configs per iteration ({cs_rate:.1} vs {nocs_rate:.1})"
    );
    assert!(
        with_cs.inference_secs <= without_cs.inference_secs * 1.15,
        "CS should preserve final quality"
    );
    println!("shape OK: CS reduces per-iteration measurements at comparable quality");
}
