//! Bench: regenerate Fig. 7 — compiled-code GFLOPS vs number of hardware
//! measurements for ResNet-18's heaviest task under each framework.

mod common;

use arco::report;
use arco::tuner::{compare_frameworks, Framework};
use arco::workload::model_by_name;

fn main() {
    arco::util::log::init_from_env();
    let model = model_by_name("resnet18").unwrap();
    let report_ = compare_frameworks(
        &Framework::paper_set(),
        &model,
        common::budget(),
        true,
        common::seed(),
    )
    .unwrap();
    let csv = report::fig7_convergence(&report_);
    report::write_result("fig7_convergence_resnet18.csv", &csv).unwrap();
    println!("{}", csv.lines().take(12).collect::<Vec<_>>().join("\n"));
    println!("... ({} rows) -> results/fig7_convergence_resnet18.csv", csv.lines().count());

    // Shape: ARCO's final best GFLOPS >= both baselines' (it can reshape
    // the hardware).
    let final_best = |f: Framework| {
        report_
            .outcome(f)
            .unwrap()
            .tasks
            .iter()
            .map(|t| t.result.best.gflops)
            .fold(0.0f64, f64::max)
    };
    let (a, c, o) = (
        final_best(Framework::AutoTvm),
        final_best(Framework::Chameleon),
        final_best(Framework::Arco),
    );
    println!("peak GFLOPS: autotvm {a:.1}, chameleon {c:.1}, arco {o:.1}");
    assert!(o >= a.max(c) * 0.98, "ARCO should reach at least baseline peak GFLOPS");
}
