//! Bench: regenerate Table 6 — mean inference times (s) on VTA++ for
//! AutoTVM / CHAMELEON / ARCO across the zoo.
//!
//! Scale with ARCO_BENCH_TRIALS (default 192) and ARCO_BENCH_MODELS
//! (default alexnet,resnet18,vgg11; "all" for the paper's seven).

mod common;

use arco::report;

fn main() {
    arco::util::log::init_from_env();
    let reports = common::run_paper_comparison();
    let table = report::table6_inference(&reports);
    println!("\nTable 6 — mean inference times (s) on VTA++:\n{table}");
    let path = report::write_result("table6_inference.md", &table).unwrap();
    println!("wrote {}", path.display());

    // Shape assertion: ARCO never slower than AutoTVM on any model.
    for r in &reports {
        let auto = r.outcome(arco::tuner::Framework::AutoTvm).unwrap().inference_secs;
        let ours = r.outcome(arco::tuner::Framework::Arco).unwrap().inference_secs;
        assert!(
            ours <= auto * 1.05,
            "{}: ARCO {ours} vs AutoTVM {auto} — Table 6 shape violated",
            r.model
        );
    }
    println!("shape OK: ARCO <= AutoTVM inference time on every model");
}
