//! Micro-benchmarks of every hot component (the §Perf profiling harness):
//! simulator instruction throughput, codegen lowering, GBT fit/predict,
//! MARL backend calls (native and, when artifacts exist, XLA).

mod common;

use arco::codegen::{lower_conv, measure_point};
use arco::costmodel::{featurize, CostModel, Gbt};
use arco::eval::{BackendKind, Engine, EngineConfig};
use arco::marl::Backend;
use arco::runtime::ModelDims;
use arco::space::{ConfigSpace, PointConfig, SwConfig};
use arco::util::bench::BenchRunner;
use arco::util::rng::Pcg32;
use arco::vta::{simulate, VtaConfig};
use arco::workload::Conv2dTask;

fn main() {
    arco::util::log::init_from_env();
    let mut runner = BenchRunner::new("micro");
    let task = Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1);
    let hw = VtaConfig::default();
    let sw = SwConfig { tile_h: 8, tile_w: 8, h_threading: 2, oc_threading: 1 };

    // Codegen lowering.
    runner.bench("codegen/lower_conv_56x56", || lower_conv(&task, &hw, &sw).unwrap());

    // Simulator throughput (elements = instructions per call).
    let kernel = lower_conv(&task, &hw, &sw).unwrap();
    let n_instr = kernel.stream.len() as u64;
    runner.bench_with_elements("sim/pipeline_56x56", Some(n_instr), || {
        arco::util::bench::black_box(simulate(&kernel.stream, &hw).unwrap());
    });

    // End-to-end measurement (decode + lower + simulate).
    let space = ConfigSpace::for_task(&task, true);
    let point = space.default_point();
    runner.bench("measure/measure_point", || measure_point(&space, &point));

    // The eval::Engine on top of the same oracle. Two views:
    //  - worker scaling on a 64-unique-point batch (the per-iteration shape
    //    of a baseline tuning loop, serial vs parallel);
    //  - cached vs uncached throughput on a repeated-point workload (the
    //    shape of `arco compare`, where frameworks revisit configurations).
    // Cache-off engines hold no cross-call state, so one engine per
    // (workers, cache) setting is shared across benches.
    let mut erng = Pcg32::seeded(41);
    let uniq64: Vec<PointConfig> = (0..64).map(|_| space.random_point(&mut erng)).collect();
    let repeated: Vec<PointConfig> =
        (0..64).map(|i| uniq64[i % 8].clone()).collect();
    let eng_w1 = Engine::new(EngineConfig { workers: 1, cache: false, ..Default::default() })
        .expect("local engine");
    let eng_w4 = Engine::new(EngineConfig { workers: 4, cache: false, ..Default::default() })
        .expect("local engine");
    let eng_cached = Engine::new(EngineConfig { workers: 4, cache: true, ..Default::default() })
        .expect("local engine");
    let n64 = Some(64u64);
    runner.bench_with_elements("eval/batch64_unique_serial_w1", n64, || {
        arco::util::bench::black_box(eng_w1.measure_batch(&space, &uniq64));
    });
    runner.bench_with_elements("eval/batch64_unique_parallel_w4", n64, || {
        arco::util::bench::black_box(eng_w4.measure_batch(&space, &uniq64));
    });
    runner.bench_with_elements("eval/batch64_repeated_uncached", n64, || {
        arco::util::bench::black_box(eng_w4.measure_batch(&space, &repeated));
    });
    runner.bench_with_elements("eval/batch64_repeated_cached", n64, || {
        arco::util::bench::black_box(eng_cached.measure_batch(&space, &repeated));
    });
    // A capacity-bounded cache on the same repeated workload (8 unique
    // points, capacity 8): every hit pays the LRU recency update — the
    // steady-state overhead a long-lived fleet shard adds per lookup.
    let eng_lru = Engine::new(EngineConfig {
        workers: 4,
        cache: true,
        cache_capacity: Some(8),
        ..Default::default()
    })
    .expect("local engine");
    runner.bench_with_elements("eval/batch64_repeated_lru_cap8", n64, || {
        arco::util::bench::black_box(eng_lru.measure_batch(&space, &repeated));
    });
    // The analytical proxy backend on the same repeated workload.
    let eng_analytical = Engine::new(EngineConfig {
        backend: BackendKind::Analytical.into(),
        workers: 4,
        cache: false,
        ..Default::default()
    })
    .expect("local engine");
    runner.bench_with_elements("eval/batch64_repeated_analytical", n64, || {
        arco::util::bench::black_box(eng_analytical.measure_batch(&space, &repeated));
    });

    // Featurization + GBT.
    let mut rng = Pcg32::seeded(1);
    runner.bench("costmodel/featurize", || featurize(&space, &point));
    let xs: Vec<Vec<f64>> = (0..512)
        .map(|_| featurize(&space, &space.random_point(&mut rng)))
        .collect();
    let ys: Vec<f64> = xs.iter().map(|f| f.iter().sum::<f64>()).collect();
    let mut gbt = Gbt::default();
    runner.bench("costmodel/gbt_fit_512", || {
        let mut m = Gbt::default();
        m.fit(&xs, &ys);
        m
    });
    gbt.fit(&xs, &ys);
    runner.bench("costmodel/gbt_predict", || gbt.predict(&xs[0]));

    // MARL backend calls.
    let dims = ModelDims::default();
    for backend in backends(dims) {
        let name = backend.name();
        let mut rng = Pcg32::seeded(2);
        let params: Vec<f32> = (0..dims.p_policy).map(|_| rng.gen_f32() * 0.1).collect();
        let vparams: Vec<f32> = (0..dims.p_value).map(|_| rng.gen_f32() * 0.1).collect();
        let obs: Vec<f32> = (0..dims.b_pol * dims.obs_dim).map(|_| rng.gen_f32()).collect();
        let state: Vec<f32> = (0..dims.b_pol * dims.gstate_dim).map(|_| rng.gen_f32()).collect();
        let mask = vec![1.0f32; dims.act_dim];
        runner.bench_with_elements(
            &format!("backend[{name}]/policy_forward_b64"),
            Some(dims.b_pol as u64),
            || {
                arco::util::bench::black_box(backend.policy_forward(&params, &obs, &mask));
            },
        );
        runner.bench_with_elements(
            &format!("backend[{name}]/value_forward_b64"),
            Some(dims.b_pol as u64),
            || {
                arco::util::bench::black_box(backend.value_forward(&vparams, &state));
            },
        );
        let rewards = vec![0.1f32; dims.t_gae];
        let values = vec![0.05f32; dims.t_gae];
        runner.bench(&format!("backend[{name}]/gae_t512"), || {
            arco::util::bench::black_box(backend.gae(&rewards, &values, 0.0, 0.99, 0.95));
        });
    }
    runner.finish();
}

fn backends(dims: ModelDims) -> Vec<Backend> {
    let mut v = vec![Backend::native(dims)];
    let dir = arco::runtime::manifest::artifacts_dir();
    if dir.join("manifest.json").exists() {
        if let Ok(b) = Backend::xla(&dir) {
            v.push(b);
        }
    }
    v
}
