//! Wire codec micro-benchmarks: the per-frame cost of the measurement
//! protocol, streaming vs the legacy JSON-tree paths.
//!
//! The unit of work is the fleet's hot frame pair: a 64-point `measure`
//! request and its 64-result `results` response (the per-iteration batch
//! shape of a tuning loop), plus the single journal record line. Encode
//! benches serialize into a reused buffer, as `RemoteBackend` and the
//! shard do into their socket buffers; decode benches parse one
//! pre-rendered line, as `serve-measure` and the client reply path do.

use arco::eval::proto::{
    record_from_json, record_from_line, record_to_json, request_from_line, response_from_line,
    write_frame, write_record_line, write_request_frame, write_response_frame, Request, Response,
};
use arco::eval::{MeasureResult, PointKey};
use arco::space::ConfigSpace;
use arco::util::bench::{black_box, BenchRunner};
use arco::util::json::Json;
use arco::util::rng::Pcg32;
use arco::workload::Conv2dTask;

fn main() {
    arco::util::log::init_from_env();
    let mut runner = BenchRunner::new("codec");
    let space = ConfigSpace::for_task(&Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1), true);
    let mut rng = Pcg32::seeded(17);
    let points: Vec<Vec<usize>> =
        (0..64).map(|_| PointKey::of(&space, &space.random_point(&mut rng)).values).collect();
    let request = Request::Measure { task: space.task, points };
    let results: Vec<MeasureResult> = (0..64)
        .map(|i| {
            let valid = i % 9 != 0;
            MeasureResult {
                seconds: if valid { 1.5e-3 + i as f64 * 1e-6 } else { f64::INFINITY },
                cycles: if valid { 1_000_000 + i as u64 * 977 } else { 0 },
                gflops: 40.0 + i as f64,
                area_mm2: 3.25,
                occupancy: 0.5,
                valid,
            }
        })
        .collect();
    let fresh: Vec<bool> = (0..64).map(|i| i % 3 != 0).collect();
    let response = Response::Results { results, fresh, active_batches: Some(3) };
    let elems = Some(64u64);

    // Encode: straight into a reused byte buffer (the socket-buffer shape).
    let mut buf: Vec<u8> = Vec::with_capacity(16 * 1024);
    runner.bench_with_elements("encode/request64_stream", elems, || {
        buf.clear();
        write_request_frame(&mut buf, &request).unwrap();
        black_box(buf.len());
    });
    runner.bench_with_elements("encode/request64_tree", elems, || {
        buf.clear();
        write_frame(&mut buf, &request.to_json()).unwrap();
        black_box(buf.len());
    });
    runner.bench_with_elements("encode/response64_stream", elems, || {
        buf.clear();
        write_response_frame(&mut buf, &response).unwrap();
        black_box(buf.len());
    });
    runner.bench_with_elements("encode/response64_tree", elems, || {
        buf.clear();
        write_frame(&mut buf, &response.to_json()).unwrap();
        black_box(buf.len());
    });

    // Decode: one pre-rendered frame line per call.
    let mut line = Vec::new();
    write_request_frame(&mut line, &request).unwrap();
    let request_line = String::from_utf8(line).unwrap().trim_end().to_string();
    let mut line = Vec::new();
    write_response_frame(&mut line, &response).unwrap();
    let response_line = String::from_utf8(line).unwrap().trim_end().to_string();
    runner.bench_with_elements("decode/request64_stream", elems, || {
        black_box(request_from_line(&request_line).unwrap());
    });
    runner.bench_with_elements("decode/request64_tree", elems, || {
        black_box(Request::from_json(&Json::parse(&request_line).unwrap()).unwrap());
    });
    runner.bench_with_elements("decode/response64_stream", elems, || {
        black_box(response_from_line(&response_line).unwrap());
    });
    runner.bench_with_elements("decode/response64_tree", elems, || {
        black_box(Response::from_json(&Json::parse(&response_line).unwrap()).unwrap());
    });

    // The journal record line, both directions.
    let key = PointKey::of(&space, &space.random_point(&mut rng));
    let result = MeasureResult {
        seconds: 1.25e-3,
        cycles: 5_000_000,
        gflops: 42.0,
        area_mm2: 3.25,
        occupancy: 0.75,
        valid: true,
    };
    runner.bench("encode/record_stream", || {
        buf.clear();
        write_record_line(&mut buf, "vta-sim", &key, &result).unwrap();
        buf.len()
    });
    runner.bench("encode/record_tree", || record_to_json("vta-sim", &key, &result).dump());
    let mut line = Vec::new();
    write_record_line(&mut line, "vta-sim", &key, &result).unwrap();
    let record_line = String::from_utf8(line).unwrap().trim_end().to_string();
    runner.bench("decode/record_stream", || record_from_line(&record_line).unwrap());
    runner.bench("decode/record_tree", || {
        record_from_json(&Json::parse(&record_line).unwrap()).unwrap()
    });
    runner.finish();
}
