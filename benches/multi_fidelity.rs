//! Multi-fidelity tuning overheads (`--fidelity screen:<keep>`):
//!
//!  - `screen/score_batch64` — the screening hot path: one calibrated
//!    analytical evaluation per candidate (what every admitted batch pays
//!    before the split);
//!  - `screen/calibration_observe` — the online-calibration update fed by
//!    every fresh cycle-model point, plus the per-batch overlap lookup;
//!  - `tune/quick128_exact` vs `tune/quick128_screen25` — the end-to-end
//!    quick-scale loop at both fidelities on the analytical oracle, so a
//!    regression in the screening stage (or any screening cost leaking
//!    into the exact path, which must stay bit-identical to the classic
//!    loop) shows up in the bench trend.

use arco::eval::{
    analytical_terms, AnalyticalBackend, Calibration, Engine, Fingerprint, SEED_OVERLAP,
};
use arco::space::{ConfigSpace, PointConfig};
use arco::tuner::{tune_task_with, Fidelity, Framework, TuneBudget};
use arco::util::bench::{black_box, BenchRunner};
use arco::util::rng::Pcg32;
use arco::workload::Conv2dTask;

fn main() {
    arco::util::log::init_from_env();
    let mut runner = BenchRunner::new("multi_fidelity");
    let task = Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1);
    let space = ConfigSpace::for_task(&task, true);
    let mut rng = Pcg32::seeded(61);
    let batch: Vec<PointConfig> = (0..64).map(|_| space.random_point(&mut rng)).collect();

    // Screening hot path: one calibrated analytical score per candidate.
    runner.bench_with_elements("screen/score_batch64", Some(64), || {
        for p in &batch {
            black_box(AnalyticalBackend::measure_with_overlaps(&space, p, SEED_OVERLAP));
        }
    });

    // Online calibration: the per-point least-squares update every fresh
    // cycle-model measurement feeds, and the per-batch overlap lookup.
    let calib = Calibration::new(Fingerprint::current());
    let terms: Vec<_> = batch
        .iter()
        .map(|p| analytical_terms(&space, p))
        .filter(|t| t.valid)
        .collect();
    let n_terms = terms.len() as u64;
    runner.bench_with_elements("screen/calibration_observe", Some(n_terms), || {
        for t in &terms {
            calib.observe("bench", t, 1_000_000);
        }
    });
    runner.bench("screen/calibration_overlaps", || black_box(calib.overlaps("bench")));

    // End-to-end quick-scale tuning (configs/quick.json's 128-point
    // budget) at both fidelities. Elements are *candidates*, so per-point
    // cost stays comparable across tiers even though screening sends far
    // fewer of them to the (here analytical) simulator.
    let quick = |fidelity| TuneBudget {
        total_measurements: 128,
        batch: 32,
        workers: 2,
        fidelity,
        ..Default::default()
    };
    runner.bench_with_elements("tune/quick128_exact", Some(128), || {
        let engine = Engine::with_backend(Box::new(AnalyticalBackend), 2, true);
        let mut strat = Framework::Random.build(space.clone(), true, 13);
        black_box(
            tune_task_with(&engine, &space, strat.as_mut(), quick(Fidelity::Exact)).unwrap(),
        );
    });
    runner.bench_with_elements("tune/quick128_screen25", Some(128), || {
        let engine = Engine::with_backend(Box::new(AnalyticalBackend), 2, true);
        let mut strat = Framework::Random.build(space.clone(), true, 13);
        black_box(
            tune_task_with(
                &engine,
                &space,
                strat.as_mut(),
                quick(Fidelity::Screen { keep: 0.25, explore: 0.1 }),
            )
            .unwrap(),
        );
    });

    runner.finish();
}
