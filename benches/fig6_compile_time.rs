//! Bench: regenerate Fig. 6 — optimization (compilation) time comparison.
//! Metric: modeled time-to-parity with AutoTVM's final quality (the
//! testbed-independent reading of "same compilation duration"); the paper
//! reports ARCO up to 42.2% faster.

mod common;

use arco::report;
use arco::tuner::Framework;

fn main() {
    arco::util::log::init_from_env();
    let reports = common::run_paper_comparison();
    let csv = report::fig6_compile_time(&reports);
    println!("\n{csv}");
    report::write_result("fig6_compile_time.csv", &csv).unwrap();

    for r in &reports {
        let auto = r.compile_secs_to_parity(Framework::AutoTvm).unwrap();
        let ours = r.compile_secs_to_parity(Framework::Arco).unwrap();
        println!(
            "{}: ARCO reaches AutoTVM quality in {ours:.1}s vs {auto:.1}s ({:+.1}%)",
            r.model,
            (1.0 - ours / auto) * 100.0
        );
    }
}
